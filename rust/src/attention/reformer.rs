//! Reformer-style LSH attention (Kitaev et al., 2020): bucket tokens by
//! hash, run exact softmax *within* each bucket, average over rounds.
//! O(sum_b |bucket_b|^2) ~ O(n^2 / 2^bits) expected — the bucketed
//! realization (no n x n matrix).

use super::Attention;
use crate::lsh::{Hasher, HyperplaneHasher};
use crate::tensor::{linalg, Mat};
use crate::util::Rng;

pub struct Reformer {
    pub rounds: usize,
    pub bucket_bits: usize,
}

impl Attention for Reformer {
    fn name(&self) -> &'static str {
        "reformer"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, rng: &mut Rng) -> Mat {
        let n = q.rows;
        let d = q.cols;
        let dv = v.cols;
        let scale = 1.0 / (d as f32).sqrt();
        let qn = q.unit_rows();
        let kn = k.unit_rows();
        let hasher = HyperplaneHasher::new(rng, self.rounds, d, self.bucket_bits);
        let cq = hasher.hash_all(&qn);
        let ck = hasher.hash_all(&kn);
        let n_buckets = 1usize << self.bucket_bits;

        let mut out = Mat::zeros(n, dv);
        let mut scores: Vec<f32> = Vec::new();
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); n_buckets];
        for r in 0..self.rounds {
            for m in members.iter_mut() {
                m.clear();
            }
            for j in 0..n {
                members[ck[r * n + j] as usize].push(j as u32);
            }
            for i in 0..n {
                let bucket = &members[cq[r * n + i] as usize];
                // fall back to self-attention on the own token when the
                // bucket is empty (Reformer always attends to itself).
                let qrow = q.row(i);
                scores.clear();
                let mut mx = f32::NEG_INFINITY;
                if bucket.is_empty() {
                    linalg::axpy(1.0 / self.rounds as f32, v.row(i), out.row_mut(i));
                    continue;
                }
                for &j in bucket {
                    let s = linalg::dot(qrow, k.row(j as usize)) * scale;
                    scores.push(s);
                    mx = mx.max(s);
                }
                let mut z = 0.0;
                for s in scores.iter_mut() {
                    *s = (*s - mx).exp();
                    z += *s;
                }
                let orow = out.row_mut(i);
                let invr = 1.0 / self.rounds as f32;
                for (s, &j) in scores.iter().zip(bucket) {
                    linalg::axpy(s / z * invr, v.row(j as usize), orow);
                }
            }
        }
        out
    }

    fn workspace_bytes(&self, n: usize, _d: usize) -> usize {
        // codes both sides + bucket membership lists + hash_all's
        // transient (n, rounds·bits) projection block (matmul-backed
        // hashing; one side live at a time)
        2 * self.rounds * n * 4 + n * 4 + n * self.rounds * self.bucket_bits * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bits_single_bucket_equals_softmax() {
        use crate::attention::SoftmaxAttention;
        let mut rng = Rng::new(0);
        let q = Mat::randn(16, 8, 1.0, &mut rng);
        let k = Mat::randn(16, 8, 1.0, &mut rng);
        let v = Mat::randn(16, 8, 1.0, &mut rng);
        let r = Reformer { rounds: 1, bucket_bits: 0 }.forward(&q, &k, &v, &mut rng);
        let s = SoftmaxAttention.forward(&q, &k, &v, &mut rng);
        assert!(r.max_abs_diff(&s) < 1e-4);
    }

    #[test]
    fn output_finite_with_skewed_buckets() {
        let mut rng = Rng::new(1);
        let q = Mat::randn(64, 16, 1.0, &mut rng);
        let k = Mat::from_fn(64, 16, |_, j| if j == 0 { 1.0 } else { 0.0 });
        let v = Mat::randn(64, 16, 1.0, &mut rng);
        let out = Reformer { rounds: 2, bucket_bits: 5 }.forward(&q, &k, &v, &mut rng);
        assert!(out.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn attends_mostly_to_similar_tokens() {
        // Token 0's query equals key 1 exactly; with enough bits they
        // share a bucket w.h.p. and the output at 0 approaches v[1].
        let mut rng = Rng::new(2);
        let d = 16;
        let n = 32;
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let mut q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        for j in 0..d {
            q.set(0, j, k.at(1, j) * 20.0);
        }
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let out = Reformer { rounds: 4, bucket_bits: 2 }.forward(&q, &k, &v, &mut rng);
        let err: f32 = (0..d).map(|j| (out.at(0, j) - v.at(1, j)).abs()).sum::<f32>() / d as f32;
        assert!(err < 0.6, "{err}");
    }
}
