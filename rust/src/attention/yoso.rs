//! YOSO attention: the paper's Figure-3 algorithm.
//!
//! For each of m hashes: hash keys, scatter-add each value row into the
//! bucket table `H[f(K_j)] += V_j` (size 2^tau x dv, *independent* of
//! bucket skew — Remark 3), then gather `Y_i += H[f(Q_i)]`. Averaging
//! over hashes and l2-normalizing gives N-YOSO. The table is reused
//! across hashes, so auxiliary memory is O(2^tau * dv), the paper's
//! memory-optimized variant.
//!
//! Two kernels implement the hot path behind [`KernelVariant`]:
//! the seed repo's loop (`Seed`, preserved verbatim as the A/B baseline
//! and oracle) and the fused arena-backed kernel (`Fused`, the default —
//! see `attention::kernel`). Outputs are bit-identical; the variant is a
//! pure performance knob selected by `YOSO_KERNEL` at construction.
//!
//! `YosoE` computes the expectation (infinite hashes) exactly — O(n^2) —
//! and is the reference for Figures 1, 6, 8.

use super::kernel::{self, KernelArena, KernelVariant};
use super::Attention;
use crate::lsh::{collision_probability, Hasher, HyperplaneHasher,
                 HadamardHasher};
use crate::tensor::Mat;
use crate::util::Rng;

/// Sampled YOSO-m attention.
#[derive(Clone)]
pub struct YosoAttention {
    pub tau: usize,
    pub m: usize,
    /// Use the fast-Hadamard projection (requires d to be a power of two).
    pub fast_hash: bool,
    /// l2-normalize the output rows (N-YOSO). On by default.
    pub normalize: bool,
    /// Which kernel runs the hot path (`attention::kernel`); defaults to
    /// `YOSO_KERNEL` (fused unless `seed`). Bit-identical outputs.
    pub kernel: KernelVariant,
}

impl YosoAttention {
    pub fn new(tau: usize, m: usize, fast_hash: bool) -> Self {
        YosoAttention {
            tau,
            m,
            fast_hash,
            normalize: true,
            kernel: KernelVariant::from_env(),
        }
    }

    /// Builder-style kernel selection (benches and the A/B tests pin the
    /// variant explicitly instead of inheriting `YOSO_KERNEL`).
    pub fn with_kernel(mut self, kernel: KernelVariant) -> Self {
        self.kernel = kernel;
        self
    }

    /// Forward pass returning the raw (unnormalized) B-hat V estimate.
    /// Queries and keys may differ in count (cross-attention / probes).
    pub fn forward_raw(&self, q: &Mat, k: &Mat, v: &Mat, rng: &mut Rng) -> Mat {
        self.forward_raw_traced(q, k, v, rng).0
    }

    /// `forward_raw` plus a trace of the auxiliary memory the pass
    /// requires — lets tests assert the Remark-3 property (workspace
    /// independent of bucket skew) at runtime instead of trusting the
    /// analytic `workspace_model`.
    pub fn forward_raw_traced(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        rng: &mut Rng,
    ) -> (Mat, WorkspaceTrace) {
        match self.kernel {
            KernelVariant::Seed => self.forward_seed_traced(q, k, v, rng),
            KernelVariant::Fused => kernel::with_arena(|arena| {
                let mut out = Mat::zeros(q.rows, v.cols);
                let trace =
                    kernel::forward_fused_into(self, q, k, v, rng, arena, &mut out);
                (out, trace)
            }),
        }
    }

    /// The fused kernel with an explicit arena and output buffer: zero
    /// heap allocation once both are warm — the serving hot loop's shape
    /// and what `tests/alloc_kernel.rs` asserts with the counting
    /// allocator. Ignores `self.kernel` (this *is* the fused entry).
    pub fn forward_fused_into(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        rng: &mut Rng,
        arena: &mut KernelArena,
        out: &mut Mat,
    ) -> WorkspaceTrace {
        kernel::forward_fused_into(self, q, k, v, rng, arena, out)
    }

    /// The seed repo's kernel, verbatim: per-token hashing, fresh
    /// allocations, random-offset scatter. The fused kernel's A/B
    /// baseline and bit-identity oracle.
    fn forward_seed_traced(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        rng: &mut Rng,
    ) -> (Mat, WorkspaceTrace) {
        let nq = q.rows;
        let nk = k.rows;
        let d = q.cols;
        let dv = v.cols;
        assert_eq!(k.cols, d);
        assert_eq!(v.rows, nk);

        let qn = q.unit_rows();
        let kn = k.unit_rows();
        let (codes_q, codes_k) = if self.fast_hash {
            let hasher = HadamardHasher::new(rng, self.m, d, self.tau);
            (hasher.hash_all(&qn), hasher.hash_all(&kn))
        } else {
            // hash_all_seed: the original per-token projection loop (the
            // public hash_all is matmul-backed now; codes are identical)
            let hasher = HyperplaneHasher::new(rng, self.m, d, self.tau);
            (hasher.hash_all_seed(&qn), hasher.hash_all_seed(&kn))
        };

        let n_buckets = 1usize << self.tau;
        let mut table = vec![0.0f32; n_buckets * dv]; // reused across hashes
        let mut out = Mat::zeros(nq, dv);
        let inv_m = 1.0 / self.m as f32;

        for h in 0..self.m {
            table.fill(0.0);
            // scatter: H[f(K_j)] += V_j
            for j in 0..nk {
                let b = codes_k[h * nk + j] as usize;
                let dst = &mut table[b * dv..(b + 1) * dv];
                let src = v.row(j);
                for (t, s) in dst.iter_mut().zip(src) {
                    *t += s;
                }
            }
            // gather: Y_i += H[f(Q_i)] / m
            for i in 0..nq {
                let b = codes_q[h * nq + i] as usize;
                let src = &table[b * dv..(b + 1) * dv];
                let dst = out.row_mut(i);
                for (o, s) in dst.iter_mut().zip(src) {
                    *o += inv_m * s;
                }
            }
        }
        let trace = WorkspaceTrace {
            table_bytes: table.len() * 4,
            codes_bytes: (codes_q.len() + codes_k.len()) * 4,
            scratch_bytes: 0,
        };
        (out, trace)
    }

    /// Analytic auxiliary-memory model in full generality: `nq` queries,
    /// `nk` keys, head dim `d`, value dim `dv`. Matches
    /// `forward_raw_traced`'s runtime trace exactly for the active
    /// kernel (regression-tested with `dv != d` — the seed-era model
    /// sized the table by `d` and was wrong whenever `dv != d`).
    pub fn workspace_model(&self, nq: usize, nk: usize, d: usize, dv: usize) -> usize {
        let table = (1usize << self.tau) * dv * 4;
        match self.kernel {
            KernelVariant::Seed => table + self.m * (nq + nk) * 4,
            KernelVariant::Fused => {
                table
                    + (nq + nk) * 4 // per-hash codes
                    + kernel::sort_scratch_bytes(self.tau, nk)
                    + kernel::hash_scratch_bytes(
                        self.tau,
                        self.m,
                        self.fast_hash,
                        nq.max(nk),
                        d,
                    )
                    + (nq + nk) * d * 4 // normalized q/k copies
            }
        }
    }
}

/// Auxiliary memory required by one YOSO forward pass — a pure function
/// of shape, never of bucket skew (Remark 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkspaceTrace {
    /// reused bucket table H (2^tau x dv floats)
    pub table_bytes: usize,
    /// packed hash codes for queries + keys (m·(nq+nk) for the seed
    /// kernel; nq+nk for the fused kernel's per-hash buffers)
    pub codes_bytes: usize,
    /// fused-kernel arena scratch beyond table + codes: bucket-sort
    /// buffers, hasher planes/signs + projection scratch, normalized
    /// q/k copies. 0 for the seed kernel (its equivalents are transient
    /// per-call allocations, kept untracked as-was for the A/B).
    pub scratch_bytes: usize,
}

impl WorkspaceTrace {
    pub fn total(&self) -> usize {
        self.table_bytes + self.codes_bytes + self.scratch_bytes
    }
}

impl Attention for YosoAttention {
    fn name(&self) -> &'static str {
        "yoso"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, rng: &mut Rng) -> Mat {
        let mut out = self.forward_raw(q, k, v, rng);
        if self.normalize {
            out.l2_normalize_rows();
        }
        out
    }

    fn workspace_bytes(&self, n: usize, d: usize) -> usize {
        self.workspace_model(n, n, d, d)
    }

    fn set_kernel(&mut self, kernel: KernelVariant) {
        self.kernel = kernel;
    }
}

/// Expectation attention E[B(Q,K)] V — "YOSO-E", infinite hashes.
pub struct YosoE {
    pub tau: usize,
}

impl YosoE {
    pub fn forward_raw(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let qn = q.unit_rows();
        let kn = k.unit_rows();
        let mut w = qn.matmul_t(&kn);
        for x in w.data.iter_mut() {
            *x = collision_probability(*x as f64, self.tau as u32) as f32;
        }
        w.matmul(v)
    }
}

impl Attention for YosoE {
    fn name(&self) -> &'static str {
        "yoso_e"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, _rng: &mut Rng) -> Mat {
        let mut out = self.forward_raw(q, k, v);
        out.l2_normalize_rows();
        out
    }

    fn workspace_bytes(&self, n: usize, _d: usize) -> usize {
        n * n * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::radians_between;

    fn setup(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat, Rng) {
        let mut rng = Rng::new(seed);
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        (q, k, v, rng)
    }

    #[test]
    fn sampled_converges_to_expectation() {
        // Core estimator property: YOSO-m -> YOSO-E as m grows.
        let (q, k, v, mut rng) = setup(48, 16, 0);
        let e = YosoE { tau: 4 }.forward_raw(&q, &k, &v);
        let mut errs = Vec::new();
        for m in [8usize, 64, 512] {
            let y = YosoAttention::new(4, m, false).forward_raw(&q, &k, &v, &mut rng);
            let err: f64 = (0..q.rows)
                .map(|i| radians_between(y.row(i), e.row(i)))
                .sum::<f64>()
                / q.rows as f64;
            errs.push(err);
        }
        assert!(errs[2] < errs[0], "error should shrink with m: {errs:?}");
        assert!(errs[2] < 0.2, "m=512 should be close: {errs:?}");
    }

    #[test]
    fn bucket_table_matches_naive_bernoulli() {
        // The table scatter/gather must equal the naive n^2 realization
        // with the same codes. We re-derive codes with the same RNG seed.
        let (q, k, v, _) = setup(32, 16, 3);
        let tau = 5;
        let m = 7;
        let mut rng1 = Rng::new(99);
        let y = YosoAttention::new(tau, m, false).forward_raw(&q, &k, &v, &mut rng1);

        let mut rng2 = Rng::new(99);
        let hasher = HyperplaneHasher::new(&mut rng2, m, 16, tau);
        let cq = hasher.hash_all(&q.unit_rows());
        let ck = hasher.hash_all(&k.unit_rows());
        let n = 32;
        let mut naive = Mat::zeros(n, v.cols);
        for h in 0..m {
            for i in 0..n {
                for j in 0..n {
                    if cq[h * n + i] == ck[h * n + j] {
                        for l in 0..v.cols {
                            naive.data[i * v.cols + l] += v.at(j, l) / m as f32;
                        }
                    }
                }
            }
        }
        assert!(y.max_abs_diff(&naive) < 1e-4);
    }

    #[test]
    fn hadamard_variant_close_to_gaussian_in_expectation() {
        let (q, k, v, mut rng) = setup(64, 32, 5);
        let e = YosoE { tau: 4 }.forward_raw(&q, &k, &v);
        let y = YosoAttention::new(4, 256, true).forward_raw(&q, &k, &v, &mut rng);
        let err: f64 = (0..q.rows)
            .map(|i| radians_between(y.row(i), e.row(i)))
            .sum::<f64>()
            / q.rows as f64;
        assert!(err < 0.35, "hadamard-based estimate too far: {err}");
    }

    #[test]
    fn normalized_output_is_unit() {
        let (q, k, v, mut rng) = setup(32, 16, 7);
        let out = YosoAttention::new(6, 16, false).forward(&q, &k, &v, &mut rng);
        for i in 0..out.rows {
            let norm: f32 = out.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(norm < 1.0 + 1e-4); // unit or (rarely) zero row
        }
    }

    #[test]
    fn workspace_independent_of_bucket_skew() {
        // All keys identical => one bucket holds everything; the
        // auxiliary memory required must not change (the Remark-3
        // property), unlike a per-bucket-list realization whose largest
        // list would grow with the skew. Compare a skewed-keys run
        // against a uniform-keys run via the runtime trace — under both
        // kernels.
        for variant in [KernelVariant::Seed, KernelVariant::Fused] {
            let a = YosoAttention::new(8, 4, false).with_kernel(variant);
            let (q, k_uniform, v, _) = setup(64, 16, 9);
            let k_skewed =
                Mat::from_fn(64, 16, |_, j| if j == 0 { 1.0 } else { 0.0 });
            let mut r1 = Rng::new(5);
            let (out_u, trace_u) = a.forward_raw_traced(&q, &k_uniform, &v, &mut r1);
            let mut r2 = Rng::new(5);
            let (out_s, trace_s) = a.forward_raw_traced(&q, &k_skewed, &v, &mut r2);
            assert_eq!(trace_u, trace_s, "auxiliary memory must ignore skew");
            assert_eq!(trace_u.table_bytes, (1 << 8) * 16 * 4);
            assert!(out_u.data.iter().all(|x| x.is_finite()));
            assert!(out_s.data.iter().all(|x| x.is_finite()));
            // the analytic Figure-7 model agrees with the traced workspace
            assert_eq!(a.workspace_bytes(64, 16), trace_u.total(), "{variant:?}");
        }
    }

    #[test]
    fn workspace_model_matches_trace_when_dv_differs_from_d() {
        // regression for the seed-era bug: the analytic table term used
        // d, but the real table is 2^tau x dv — wrong whenever dv != d.
        // The model must match the runtime trace in full generality
        // (nq != nk, dv != d) under both kernels and both hashers.
        let mut rng = Rng::new(21);
        let (nq, nk, d, dv) = (24, 40, 16, 48);
        let q = Mat::randn(nq, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(nk, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(nk, dv, 1.0, &mut rng);
        for variant in [KernelVariant::Seed, KernelVariant::Fused] {
            for fast in [false, true] {
                let a = YosoAttention::new(5, 6, fast).with_kernel(variant);
                let mut r = Rng::new(11);
                let (out, trace) = a.forward_raw_traced(&q, &k, &v, &mut r);
                assert_eq!((out.rows, out.cols), (nq, dv));
                assert_eq!(
                    a.workspace_model(nq, nk, d, dv),
                    trace.total(),
                    "{variant:?} fast={fast}"
                );
                assert_eq!(trace.table_bytes, (1 << 5) * dv * 4, "table is 2^tau x dv");
            }
        }
    }

    #[test]
    fn fused_into_reuses_arena_and_matches_trait_forward() {
        let (q, k, v, _) = setup(48, 16, 13);
        let att = YosoAttention::new(6, 8, false).with_kernel(KernelVariant::Fused);
        let mut r1 = Rng::new(7);
        let reference = att.forward_raw(&q, &k, &v, &mut r1);
        let mut arena = KernelArena::new();
        let mut out = Mat::zeros(q.rows, v.cols);
        for _ in 0..3 {
            // repeated in-place forwards with one arena: same bytes
            let mut r2 = Rng::new(7);
            att.forward_fused_into(&q, &k, &v, &mut r2, &mut arena, &mut out);
            for (a, b) in out.data.iter().zip(&reference.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
