//! YOSO attention: the paper's Figure-3 algorithm, verbatim.
//!
//! For each of m hashes: hash keys, scatter-add each value row into the
//! bucket table `H[f(K_j)] += V_j` (size 2^tau x dv, *independent* of
//! bucket skew — Remark 3), then gather `Y_i += H[f(Q_i)]`. Averaging
//! over hashes and l2-normalizing gives N-YOSO. The table is reused
//! across hashes, so auxiliary memory is O(2^tau * dv), the paper's
//! memory-optimized variant.
//!
//! `YosoE` computes the expectation (infinite hashes) exactly — O(n^2) —
//! and is the reference for Figures 1, 6, 8.

use super::Attention;
use crate::lsh::{collision_probability, Hasher, HyperplaneHasher,
                 HadamardHasher};
use crate::tensor::Mat;
use crate::util::Rng;

/// Sampled YOSO-m attention.
pub struct YosoAttention {
    pub tau: usize,
    pub m: usize,
    /// Use the fast-Hadamard projection (requires d to be a power of two).
    pub fast_hash: bool,
    /// l2-normalize the output rows (N-YOSO). On by default.
    pub normalize: bool,
}

impl YosoAttention {
    pub fn new(tau: usize, m: usize, fast_hash: bool) -> Self {
        YosoAttention { tau, m, fast_hash, normalize: true }
    }

    /// Forward pass returning the raw (unnormalized) B-hat V estimate.
    /// Queries and keys may differ in count (cross-attention / probes).
    pub fn forward_raw(&self, q: &Mat, k: &Mat, v: &Mat, rng: &mut Rng) -> Mat {
        self.forward_raw_traced(q, k, v, rng).0
    }

    /// `forward_raw` plus a trace of the auxiliary memory the pass
    /// actually allocated — lets tests assert the Remark-3 property
    /// (allocation independent of bucket skew) at runtime instead of
    /// trusting the analytic `workspace_bytes` model.
    pub fn forward_raw_traced(
        &self,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        rng: &mut Rng,
    ) -> (Mat, WorkspaceTrace) {
        let nq = q.rows;
        let nk = k.rows;
        let d = q.cols;
        let dv = v.cols;
        assert_eq!(k.cols, d);
        assert_eq!(v.rows, nk);

        let qn = q.unit_rows();
        let kn = k.unit_rows();
        let (codes_q, codes_k) = if self.fast_hash {
            let hasher = HadamardHasher::new(rng, self.m, d, self.tau);
            (hasher.hash_all(&qn), hasher.hash_all(&kn))
        } else {
            let hasher = HyperplaneHasher::new(rng, self.m, d, self.tau);
            (hasher.hash_all(&qn), hasher.hash_all(&kn))
        };

        let n_buckets = 1usize << self.tau;
        let mut table = vec![0.0f32; n_buckets * dv]; // reused across hashes
        let mut out = Mat::zeros(nq, dv);
        let inv_m = 1.0 / self.m as f32;

        for h in 0..self.m {
            table.fill(0.0);
            // scatter: H[f(K_j)] += V_j
            for j in 0..nk {
                let b = codes_k[h * nk + j] as usize;
                let dst = &mut table[b * dv..(b + 1) * dv];
                let src = v.row(j);
                for (t, s) in dst.iter_mut().zip(src) {
                    *t += s;
                }
            }
            // gather: Y_i += H[f(Q_i)] / m
            for i in 0..nq {
                let b = codes_q[h * nq + i] as usize;
                let src = &table[b * dv..(b + 1) * dv];
                let dst = out.row_mut(i);
                for (o, s) in dst.iter_mut().zip(src) {
                    *o += inv_m * s;
                }
            }
        }
        let trace = WorkspaceTrace {
            table_bytes: table.len() * 4,
            codes_bytes: (codes_q.len() + codes_k.len()) * 4,
        };
        (out, trace)
    }
}

/// Auxiliary memory actually allocated by one YOSO forward pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkspaceTrace {
    /// reused bucket table H (2^tau x dv floats)
    pub table_bytes: usize,
    /// packed hash codes for queries + keys
    pub codes_bytes: usize,
}

impl WorkspaceTrace {
    pub fn total(&self) -> usize {
        self.table_bytes + self.codes_bytes
    }
}

impl Attention for YosoAttention {
    fn name(&self) -> &'static str {
        "yoso"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, rng: &mut Rng) -> Mat {
        let mut out = self.forward_raw(q, k, v, rng);
        if self.normalize {
            out.l2_normalize_rows();
        }
        out
    }

    fn workspace_bytes(&self, n: usize, d: usize) -> usize {
        // reused bucket table + packed codes for both sides
        (1 << self.tau) * d * 4 + 2 * self.m * n * 4
    }
}

/// Expectation attention E[B(Q,K)] V — "YOSO-E", infinite hashes.
pub struct YosoE {
    pub tau: usize,
}

impl YosoE {
    pub fn forward_raw(&self, q: &Mat, k: &Mat, v: &Mat) -> Mat {
        let qn = q.unit_rows();
        let kn = k.unit_rows();
        let mut w = qn.matmul_t(&kn);
        for x in w.data.iter_mut() {
            *x = collision_probability(*x as f64, self.tau as u32) as f32;
        }
        w.matmul(v)
    }
}

impl Attention for YosoE {
    fn name(&self) -> &'static str {
        "yoso_e"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, _rng: &mut Rng) -> Mat {
        let mut out = self.forward_raw(q, k, v);
        out.l2_normalize_rows();
        out
    }

    fn workspace_bytes(&self, n: usize, _d: usize) -> usize {
        n * n * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::radians_between;

    fn setup(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat, Rng) {
        let mut rng = Rng::new(seed);
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        (q, k, v, rng)
    }

    #[test]
    fn sampled_converges_to_expectation() {
        // Core estimator property: YOSO-m -> YOSO-E as m grows.
        let (q, k, v, mut rng) = setup(48, 16, 0);
        let e = YosoE { tau: 4 }.forward_raw(&q, &k, &v);
        let mut errs = Vec::new();
        for m in [8usize, 64, 512] {
            let y = YosoAttention::new(4, m, false).forward_raw(&q, &k, &v, &mut rng);
            let err: f64 = (0..q.rows)
                .map(|i| radians_between(y.row(i), e.row(i)))
                .sum::<f64>()
                / q.rows as f64;
            errs.push(err);
        }
        assert!(errs[2] < errs[0], "error should shrink with m: {errs:?}");
        assert!(errs[2] < 0.2, "m=512 should be close: {errs:?}");
    }

    #[test]
    fn bucket_table_matches_naive_bernoulli() {
        // The table scatter/gather must equal the naive n^2 realization
        // with the same codes. We re-derive codes with the same RNG seed.
        let (q, k, v, _) = setup(32, 16, 3);
        let tau = 5;
        let m = 7;
        let mut rng1 = Rng::new(99);
        let y = YosoAttention::new(tau, m, false).forward_raw(&q, &k, &v, &mut rng1);

        let mut rng2 = Rng::new(99);
        let hasher = HyperplaneHasher::new(&mut rng2, m, 16, tau);
        let cq = hasher.hash_all(&q.unit_rows());
        let ck = hasher.hash_all(&k.unit_rows());
        let n = 32;
        let mut naive = Mat::zeros(n, v.cols);
        for h in 0..m {
            for i in 0..n {
                for j in 0..n {
                    if cq[h * n + i] == ck[h * n + j] {
                        for l in 0..v.cols {
                            naive.data[i * v.cols + l] += v.at(j, l) / m as f32;
                        }
                    }
                }
            }
        }
        assert!(y.max_abs_diff(&naive) < 1e-4);
    }

    #[test]
    fn hadamard_variant_close_to_gaussian_in_expectation() {
        let (q, k, v, mut rng) = setup(64, 32, 5);
        let e = YosoE { tau: 4 }.forward_raw(&q, &k, &v);
        let y = YosoAttention::new(4, 256, true).forward_raw(&q, &k, &v, &mut rng);
        let err: f64 = (0..q.rows)
            .map(|i| radians_between(y.row(i), e.row(i)))
            .sum::<f64>()
            / q.rows as f64;
        assert!(err < 0.35, "hadamard-based estimate too far: {err}");
    }

    #[test]
    fn normalized_output_is_unit() {
        let (q, k, v, mut rng) = setup(32, 16, 7);
        let out = YosoAttention::new(6, 16, false).forward(&q, &k, &v, &mut rng);
        for i in 0..out.rows {
            let norm: f32 = out.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(norm < 1.0 + 1e-4); // unit or (rarely) zero row
        }
    }

    #[test]
    fn workspace_independent_of_bucket_skew() {
        // All keys identical => one bucket holds everything; the
        // auxiliary memory actually allocated must not change (the
        // Remark-3 property), unlike a per-bucket-list realization whose
        // largest list would grow with the skew. Compare a skewed-keys
        // run against a uniform-keys run via the runtime trace.
        let a = YosoAttention::new(8, 4, false);
        let (q, k_uniform, v, _) = setup(64, 16, 9);
        let k_skewed =
            Mat::from_fn(64, 16, |_, j| if j == 0 { 1.0 } else { 0.0 });
        let mut r1 = Rng::new(5);
        let (out_u, trace_u) = a.forward_raw_traced(&q, &k_uniform, &v, &mut r1);
        let mut r2 = Rng::new(5);
        let (out_s, trace_s) = a.forward_raw_traced(&q, &k_skewed, &v, &mut r2);
        assert_eq!(trace_u, trace_s, "auxiliary memory must ignore skew");
        assert_eq!(trace_u.table_bytes, (1 << 8) * 16 * 4);
        assert!(out_u.data.iter().all(|x| x.is_finite()));
        assert!(out_s.data.iter().all(|x| x.is_finite()));
        // the analytic Figure-7 model agrees with the traced allocation
        assert_eq!(a.workspace_bytes(64, 16), trace_u.total());
    }
}
