//! Linear Transformer (Katharopoulos et al., 2020): kernelized attention
//! with the elu(x)+1 feature map — O(n * d^2), the simplest linear
//! baseline in the paper's §2.2 taxonomy.

use super::Attention;
use crate::tensor::{linalg, Mat};
use crate::util::Rng;

pub struct LinearTransformer;

fn elu1(x: f32) -> f32 {
    if x > 0.0 {
        x + 1.0
    } else {
        x.exp() // elu(x) + 1 = exp(x) for x <= 0
    }
}

impl Attention for LinearTransformer {
    fn name(&self) -> &'static str {
        "linear"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, _rng: &mut Rng) -> Mat {
        let phi_q = q.map(elu1); // (n, d)
        let phi_k = k.map(elu1);
        // kv = phi_k^T v : (d, dv); ksum = sum_j phi_k_j : (d,)
        let kv = phi_k.t().matmul(v);
        let mut ksum = vec![0.0f32; phi_k.cols];
        for j in 0..phi_k.rows {
            for (s, x) in ksum.iter_mut().zip(phi_k.row(j)) {
                *s += x;
            }
        }
        let mut out = phi_q.matmul(&kv); // (n, dv)
        for i in 0..out.rows {
            let z = linalg::dot(phi_q.row(i), &ksum).max(1e-6);
            let inv = 1.0 / z;
            for x in out.row_mut(i) {
                *x *= inv;
            }
        }
        out
    }

    fn workspace_bytes(&self, _n: usize, d: usize) -> usize {
        (d * d + d) * 4
    }
}

/// Depthwise convolution residual on values — the YOSO-C / Nyströmformer
/// augmentation (§4.2): one 1-D filter applied along the token axis,
/// added to the attention output.
pub fn depthwise_conv_residual(v: &Mat, kernel: &[f32]) -> Mat {
    let n = v.rows;
    let dv = v.cols;
    let ks = kernel.len();
    let half = ks / 2;
    let mut out = Mat::zeros(n, dv);
    for i in 0..n {
        for (t, &w) in kernel.iter().enumerate() {
            let j = i as isize + t as isize - half as isize;
            if j < 0 || j >= n as isize {
                continue;
            }
            let src = v.row(j as usize);
            let dst = out.row_mut(i);
            for (o, s) in dst.iter_mut().zip(src) {
                *o += w * s;
            }
        }
    }
    out
}

/// YOSO-C: sampled YOSO attention plus a depthwise conv residual.
pub struct YosoConv {
    pub inner: super::yoso::YosoAttention,
    pub kernel: Vec<f32>,
}

impl YosoConv {
    pub fn new(tau: usize, m: usize, conv_size: usize, rng: &mut Rng) -> Self {
        let mut kernel: Vec<f32> = (0..conv_size).map(|_| 0.02 * rng.normal()).collect();
        kernel[conv_size / 2] += 1.0; // identity-ish init, as in L2
        YosoConv { inner: super::yoso::YosoAttention::new(tau, m, false), kernel }
    }
}

impl Attention for YosoConv {
    fn name(&self) -> &'static str {
        "yoso_c"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, rng: &mut Rng) -> Mat {
        let mut out = self.inner.forward_raw(q, k, v, rng);
        out.add_assign(&depthwise_conv_residual(v, &self.kernel));
        out.l2_normalize_rows();
        out
    }

    fn workspace_bytes(&self, n: usize, d: usize) -> usize {
        self.inner.workspace_bytes(n, d) + n * d * 4
    }

    fn set_kernel(&mut self, kernel: super::KernelVariant) {
        self.inner.kernel = kernel;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::SoftmaxAttention;

    #[test]
    fn constant_values_are_preserved() {
        // convex weights: constant V maps to the same constant
        let mut rng = Rng::new(0);
        let q = Mat::randn(32, 8, 1.0, &mut rng);
        let k = Mat::randn(32, 8, 1.0, &mut rng);
        let v = Mat::from_fn(32, 8, |_, _| 2.0);
        let out = LinearTransformer.forward(&q, &k, &v, &mut rng);
        for x in &out.data {
            assert!((x - 2.0).abs() < 1e-4, "{x}");
        }
    }

    #[test]
    fn tracks_softmax_at_low_temperature() {
        // with small-magnitude q/k both reduce to near-uniform averaging
        let mut rng = Rng::new(1);
        let q = Mat::randn(24, 8, 0.05, &mut rng);
        let k = Mat::randn(24, 8, 0.05, &mut rng);
        let v = Mat::randn(24, 8, 1.0, &mut rng);
        let a = LinearTransformer.forward(&q, &k, &v, &mut rng);
        let b = SoftmaxAttention.forward(&q, &k, &v, &mut rng);
        assert!(a.max_abs_diff(&b) < 0.05, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn conv_identity_kernel_is_identity() {
        let mut rng = Rng::new(2);
        let v = Mat::randn(16, 4, 1.0, &mut rng);
        let out = depthwise_conv_residual(&v, &[0.0, 1.0, 0.0]);
        assert!(out.max_abs_diff(&v) < 1e-6);
    }

    #[test]
    fn conv_shift_kernel_shifts() {
        let mut rng = Rng::new(3);
        let v = Mat::randn(16, 4, 1.0, &mut rng);
        // kernel [1, 0, 0] with center at index 1 takes the previous row
        let out = depthwise_conv_residual(&v, &[1.0, 0.0, 0.0]);
        for i in 1..16 {
            for j in 0..4 {
                assert!((out.at(i, j) - v.at(i - 1, j)).abs() < 1e-6);
            }
        }
        // first row had no left neighbor
        assert!(out.row(0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn yoso_c_finite_and_unit() {
        let mut rng = Rng::new(4);
        let q = Mat::randn(64, 16, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(64, 16, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(64, 16, 1.0, &mut rng);
        let yc = YosoConv::new(6, 8, 9, &mut rng);
        let out = yc.forward(&q, &k, &v, &mut rng);
        assert!(out.data.iter().all(|x| x.is_finite()));
        for i in 0..out.rows {
            let norm: f32 = out.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!(norm <= 1.0 + 1e-4);
        }
    }
}
