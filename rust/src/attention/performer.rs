//! Performer (Choromanski et al., 2021): FAVOR+ positive random features
//! approximating the softmax kernel — O(n * r * d).

use super::Attention;
use crate::tensor::Mat;
use crate::util::Rng;

pub struct Performer {
    pub n_features: usize,
}

impl Performer {
    fn features(&self, x: &Mat, w: &Mat) -> Mat {
        // phi(x) = exp(w.x - |x|^2/2 - max_row) / sqrt(r)
        let mut proj = x.matmul_t(w); // (n, r)
        let r = self.n_features as f32;
        for i in 0..x.rows {
            let sq: f32 = x.row(i).iter().map(|a| a * a).sum::<f32>() * 0.5;
            let row = proj.row_mut(i);
            let mx = row
                .iter()
                .map(|p| p - sq)
                .fold(f32::NEG_INFINITY, f32::max);
            for p in row.iter_mut() {
                *p = ((*p - sq) - mx).exp() / r.sqrt();
            }
        }
        proj
    }
}

impl Attention for Performer {
    fn name(&self) -> &'static str {
        "performer"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, rng: &mut Rng) -> Mat {
        let d = q.cols;
        let w = Mat::randn(self.n_features, d, 1.0, rng);
        let scale = (d as f32).powf(-0.25);
        let qs = q.map(|x| x * scale);
        let ks = k.map(|x| x * scale);
        let phi_q = self.features(&qs, &w); // (n, r)
        let phi_k = self.features(&ks, &w); // (n, r)

        let kv = phi_k.t().matmul(v); // (r, dv)
        let mut out = phi_q.matmul(&kv); // (n, dv)
        // normalizer z = phi_q . sum_j phi_k_j
        let mut ksum = vec![0.0f32; self.n_features];
        for j in 0..phi_k.rows {
            for (s, x) in ksum.iter_mut().zip(phi_k.row(j)) {
                *s += x;
            }
        }
        for i in 0..out.rows {
            let z: f32 = crate::tensor::linalg::dot(phi_q.row(i), &ksum);
            let inv = 1.0 / z.max(1e-6);
            for x in out.row_mut(i) {
                *x *= inv;
            }
        }
        out
    }

    fn workspace_bytes(&self, n: usize, d: usize) -> usize {
        (2 * n * self.n_features + self.n_features * d) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::SoftmaxAttention;

    #[test]
    fn rows_are_convex_combinations() {
        // FAVOR+ weights are positive and normalized, so constant values
        // must map to (approximately) the same constant.
        let mut rng = Rng::new(0);
        let q = Mat::randn(64, 16, 1.0, &mut rng);
        let k = Mat::randn(64, 16, 1.0, &mut rng);
        let v = Mat::from_fn(64, 8, |_, _| 3.0);
        let out = Performer { n_features: 128 }.forward(&q, &k, &v, &mut rng);
        for x in &out.data {
            assert!((x - 3.0).abs() < 1e-3, "{x}");
        }
    }

    #[test]
    fn approximates_softmax_with_many_features() {
        let mut rng = Rng::new(1);
        let q = Mat::randn(32, 8, 0.5, &mut rng);
        let k = Mat::randn(32, 8, 0.5, &mut rng);
        let v = Mat::randn(32, 8, 1.0, &mut rng);
        let exact = SoftmaxAttention.forward(&q, &k, &v, &mut rng);
        // average over feature draws
        let mut acc = Mat::zeros(32, 8);
        let reps = 20;
        for _ in 0..reps {
            let est = Performer { n_features: 512 }.forward(&q, &k, &v, &mut rng);
            acc.add_assign(&est);
        }
        acc.scale(1.0 / reps as f32);
        assert!(acc.max_abs_diff(&exact) < 0.25, "{}", acc.max_abs_diff(&exact));
    }
}
