//! Nyströmformer (Xiong et al., 2021): landmark-based attention with an
//! iterative (Newton–Schulz) pseudo-inverse — O(n * l).

use super::Attention;
use crate::tensor::Mat;
use crate::util::Rng;

pub struct Nystromformer {
    pub landmarks: usize,
}

fn softmax_scaled(mut scores: Mat, scale: f32) -> Mat {
    scores.scale(scale);
    scores.softmax_rows();
    scores
}

/// Newton–Schulz pseudo-inverse, 6 iterations as in the paper.
fn pinv_ns(a: &Mat) -> Mat {
    let l = a.rows;
    let max_col: f32 = (0..l)
        .map(|j| (0..l).map(|i| a.at(i, j).abs()).sum::<f32>())
        .fold(0.0, f32::max);
    let max_row: f32 = (0..l)
        .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f32>())
        .fold(0.0, f32::max);
    let mut z = a.t();
    z.scale(1.0 / (max_col * max_row));
    let eye = Mat::from_fn(l, l, |i, j| if i == j { 1.0 } else { 0.0 });
    for _ in 0..6 {
        // z <- 0.25 z (13 I - az (15 I - az (7 I - az))), az = a z
        // (cubic Newton–Schulz from Xiong et al.; fixed point az = I)
        let az = a.matmul(&z);
        let az2 = az.matmul(&az);
        let az3 = az2.matmul(&az);
        let mut bracket = Mat::zeros(l, l);
        for idx in 0..l * l {
            bracket.data[idx] = 13.0 * eye.data[idx] - 15.0 * az.data[idx]
                + 7.0 * az2.data[idx]
                - az3.data[idx];
        }
        z = z.matmul(&bracket);
        z.scale(0.25);
    }
    z
}

impl Attention for Nystromformer {
    fn name(&self) -> &'static str {
        "nystrom"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, _rng: &mut Rng) -> Mat {
        let n = q.rows;
        let d = q.cols;
        let l = self.landmarks.min(n);
        let seg = n / l;
        let scale = 1.0 / (d as f32).sqrt();

        // segment-mean landmarks
        let mk_landmarks = |x: &Mat| {
            Mat::from_fn(l, d, |i, j| {
                let lo = i * seg;
                let hi = if i == l - 1 { n } else { (i + 1) * seg };
                (lo..hi).map(|r| x.at(r, j)).sum::<f32>() / (hi - lo) as f32
            })
        };
        let ql = mk_landmarks(q);
        let kl = mk_landmarks(k);

        let f = softmax_scaled(q.matmul_t(&kl), scale); // (n, l)
        let a = softmax_scaled(ql.matmul_t(&kl), scale); // (l, l)
        let b = softmax_scaled(ql.matmul_t(k), scale); // (l, n)

        let z = pinv_ns(&a);
        let bv = b.matmul(v); // (l, dv)
        let zbv = z.matmul(&bv); // (l, dv)
        f.matmul(&zbv)
    }

    fn workspace_bytes(&self, n: usize, d: usize) -> usize {
        let l = self.landmarks;
        (2 * n * l + 3 * l * l + 2 * l * d) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::SoftmaxAttention;

    #[test]
    fn pinv_of_identity_is_identity() {
        let eye = Mat::from_fn(8, 8, |i, j| if i == j { 1.0 } else { 0.0 });
        let z = pinv_ns(&eye);
        assert!(z.max_abs_diff(&eye) < 1e-3);
    }

    #[test]
    fn pinv_inverts_diagonally_dominant() {
        let mut rng = Rng::new(0);
        let mut a = Mat::randn(6, 6, 0.05, &mut rng);
        for i in 0..6 {
            let x = a.at(i, i);
            a.set(i, i, x + 1.0);
        }
        let z = pinv_ns(&a);
        let prod = a.matmul(&z);
        let eye = Mat::from_fn(6, 6, |i, j| if i == j { 1.0 } else { 0.0 });
        assert!(prod.max_abs_diff(&eye) < 1e-2, "{}", prod.max_abs_diff(&eye));
    }

    #[test]
    fn landmarks_equal_n_recovers_softmax_approximately() {
        let mut rng = Rng::new(1);
        let n = 32;
        let q = Mat::randn(n, 8, 0.7, &mut rng);
        let k = Mat::randn(n, 8, 0.7, &mut rng);
        let v = Mat::randn(n, 8, 1.0, &mut rng);
        let ny = Nystromformer { landmarks: n }.forward(&q, &k, &v, &mut rng);
        let sm = SoftmaxAttention.forward(&q, &k, &v, &mut rng);
        assert!(ny.max_abs_diff(&sm) < 0.15, "{}", ny.max_abs_diff(&sm));
    }

    #[test]
    fn finite_on_long_sequences() {
        let mut rng = Rng::new(2);
        let q = Mat::randn(512, 16, 1.0, &mut rng);
        let k = Mat::randn(512, 16, 1.0, &mut rng);
        let v = Mat::randn(512, 16, 1.0, &mut rng);
        let out = Nystromformer { landmarks: 64 }.forward(&q, &k, &v, &mut rng);
        assert!(out.data.iter().all(|x| x.is_finite()));
    }
}
