//! Pure-Rust attention library: YOSO and every baseline the paper
//! compares against (§4.2), all behind one trait.
//!
//! This library is the substrate for the efficiency study (Figure 7 /
//! Table 1), the approximation studies (Figures 1, 6, 8), and the
//! serving path's CPU fallback. Training gradients live in the L2 HLO
//! artifacts; these implementations are forward-only.
//!
//! Every implementation reports its theoretical auxiliary-memory
//! footprint (`workspace_bytes`) so the memory curves of Figure 7 can be
//! reproduced both analytically and via the counting allocator in
//! `bench_support`.

pub mod engine;
pub mod kernel;
pub mod linear;
pub mod linformer;
pub mod longformer;
pub mod nystrom;
pub mod performer;
pub mod reformer;
pub mod softmax;
pub mod stream;
pub mod yoso;

pub use engine::{ChunkPolicy, Engine, HASH_CHUNK, MultiHeadAttention};
pub use kernel::{KernelArena, KernelVariant};
pub use linear::{LinearTransformer, YosoConv};
pub use linformer::Linformer;
pub use longformer::Longformer;
pub use nystrom::Nystromformer;
pub use performer::Performer;
pub use reformer::Reformer;
pub use softmax::SoftmaxAttention;
pub use stream::YosoStream;
pub use yoso::{YosoAttention, YosoE};

use crate::tensor::Mat;
use crate::util::Rng;

/// One head's (q, k, v) triple for batched multi-head execution. A
/// `[batch, heads]` workload flattens to a `Vec<HeadTask>` in row-major
/// (batch-then-head) order.
#[derive(Clone, Debug)]
pub struct HeadTask {
    pub q: Mat,
    pub k: Mat,
    pub v: Mat,
}

/// Self-attention over per-head matrices. q, k: (n, d); v: (n, dv).
///
/// `Send + Sync` so trait objects can be shared with the worker pool by
/// the parallel engine (`attention::engine`); every implementation is
/// plain owned data.
pub trait Attention: Send + Sync {
    /// Human-readable name for reports.
    fn name(&self) -> &'static str;

    /// Compute the attention output (n, dv).
    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, rng: &mut Rng) -> Mat;

    /// Forward a batch of independent heads. Head `i` draws its
    /// randomness from `rng.fold_in(i)`, so results do not depend on
    /// evaluation order — `engine::MultiHeadAttention` is the pool-backed
    /// equivalent and produces bit-identical output. Default: serial loop.
    fn forward_batch(&self, heads: &[HeadTask], rng: &Rng) -> Vec<Mat> {
        heads
            .iter()
            .enumerate()
            .map(|(i, h)| {
                let mut r = rng.fold_in(i as u64);
                self.forward(&h.q, &h.k, &h.v, &mut r)
            })
            .collect()
    }

    /// Theoretical auxiliary memory (bytes) beyond inputs/outputs for a
    /// sequence length n and head dim d — the Figure 7 memory model.
    fn workspace_bytes(&self, n: usize, d: usize) -> usize;

    /// Pin the YOSO kernel implementation (`attention::kernel`) for
    /// variants that have one; default no-op for the rest of the zoo.
    /// Lets config layers (the serve paths) select the kernel without
    /// downcasting the boxed trait object.
    fn set_kernel(&mut self, _kernel: KernelVariant) {}
}

/// Identity mixing (the LRA "None" row).
pub struct NoneAttention;

impl Attention for NoneAttention {
    fn name(&self) -> &'static str {
        "none"
    }

    fn forward(&self, _q: &Mat, _k: &Mat, v: &Mat, _rng: &mut Rng) -> Mat {
        v.clone()
    }

    fn workspace_bytes(&self, _n: usize, _d: usize) -> usize {
        0
    }
}

/// The sampled-YOSO attention a variant name denotes, when it denotes
/// one: `yoso_<m>` / `yoso_fast_<m>` with the same §4.2 hyperparameters
/// `by_name` uses (and the same `m` fallback on a malformed suffix).
/// `None` for the rest of the zoo — including `yoso_e` (exact
/// expectation, no sampled tables) and `yoso_c_*` (convolutional) —
/// which is how the serving layer decides whether a config is
/// streamable ([`stream::YosoStream`] / the gateway prefix cache).
pub fn yoso_variant(name: &str) -> Option<YosoAttention> {
    match name {
        "yoso_e" => None,
        name if name.starts_with("yoso_fast_") => {
            let m: usize = name["yoso_fast_".len()..].parse().unwrap_or(32);
            Some(YosoAttention::new(8, m, true))
        }
        name if name.starts_with("yoso_c_") => None,
        name if name.starts_with("yoso_") => {
            let m: usize = name["yoso_".len()..].parse().unwrap_or(32);
            Some(YosoAttention::new(8, m, false))
        }
        _ => None,
    }
}

/// Construct a variant by name with the paper's §4.2 hyperparameters.
pub fn by_name(name: &str, rng: &mut Rng, d: usize) -> Box<dyn Attention> {
    if let Some(yoso) = yoso_variant(name) {
        return Box::new(yoso);
    }
    match name {
        "softmax" => Box::new(SoftmaxAttention),
        "none" => Box::new(NoneAttention),
        "yoso_e" => Box::new(YosoE { tau: 8 }),
        "linear" => Box::new(LinearTransformer),
        name if name.starts_with("yoso_c_") => {
            let m: usize = name["yoso_c_".len()..].parse().unwrap_or(16);
            Box::new(YosoConv::new(8, m, 9, rng))
        }
        "linformer" => Box::new(Linformer::new(rng, 256, d)),
        "performer" => Box::new(Performer { n_features: 256 }),
        "longformer" => Box::new(Longformer { window: 256 }),
        "reformer" => Box::new(Reformer { rounds: 2, bucket_bits: 6 }),
        "nystrom" => Box::new(Nystromformer { landmarks: 64 }),
        other => panic!("unknown attention variant {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize, d: usize) -> (Mat, Mat, Mat, Rng) {
        let mut rng = Rng::new(0);
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        (q, k, v, rng)
    }

    #[test]
    fn all_variants_produce_finite_output() {
        let (q, k, v, mut rng) = setup(64, 32);
        for name in ["softmax", "none", "yoso_e", "yoso_16", "yoso_fast_16",
                     "yoso_c_16", "linear", "linformer", "performer",
                     "longformer", "reformer", "nystrom"] {
            let mut r2 = Rng::new(1);
            let attn = by_name(name, &mut r2, 32);
            let out = attn.forward(&q, &k, &v, &mut rng);
            assert_eq!((out.rows, out.cols), (64, 32), "{name}");
            assert!(out.data.iter().all(|x| x.is_finite()), "{name}");
        }
    }

    #[test]
    fn yoso_variant_mirrors_by_name_arms() {
        let v = yoso_variant("yoso_16").unwrap();
        assert!(!v.fast_hash);
        assert_eq!((v.tau, v.m), (8, 16));
        let f = yoso_variant("yoso_fast_8").unwrap();
        assert!(f.fast_hash);
        assert_eq!(f.m, 8);
        // malformed suffix falls back to by_name's default m
        assert_eq!(yoso_variant("yoso_junk").unwrap().m, 32);
        // not streamable: exact expectation, conv, and the rest of the zoo
        for name in ["yoso_e", "yoso_c_16", "softmax", "none", "reformer"] {
            assert!(yoso_variant(name).is_none(), "{name}");
        }
    }

    #[test]
    fn none_is_identity() {
        let (q, k, v, mut rng) = setup(16, 8);
        let out = NoneAttention.forward(&q, &k, &v, &mut rng);
        assert_eq!(out, v);
    }
}
