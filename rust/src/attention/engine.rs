//! Parallel multi-head YOSO forward engine.
//!
//! Two independent grains of parallelism over `util::ThreadPool`, both
//! deterministic for a given caller seed:
//!
//! * **Per-hash** (`Engine::forward_yoso`): the `m` hash rounds of one
//!   YOSO forward are embarrassingly parallel. Round `h` draws its
//!   projections from the fixed stream `rng.fold_in(h)` and scatters into
//!   its *own* bucket table. Rounds are grouped into fixed
//!   `HASH_CHUNK`-sized tasks (hashes summed ascending within a chunk,
//!   chunk accumulators reduced ascending on the caller thread), bounding
//!   transient memory at m/HASH_CHUNK accumulators. Every term and every
//!   association of the reduction is a constant of the algorithm — never
//!   of the thread count — so output bytes are identical for every
//!   thread count, including the serial engine.
//! * **Per-head** (`MultiHeadAttention::forward_batch`): independent
//!   `[batch, heads] x (Q, K, V)` tasks fan across the pool; head `i`
//!   draws from `rng.fold_in(i)`, matching the serial default
//!   `Attention::forward_batch` bit-for-bit.
//!
//! Note: the engine's per-hash streams differ from the *legacy*
//! single-stream draw order of `YosoAttention::forward` (one hasher
//! object drawing all m rounds from one sequence). Both are unbiased
//! samples of the same estimator; "bit-identical" guarantees here relate
//! engine runs at different thread counts, not engine vs legacy.
//!
//! Deadlock rule: jobs running *on* a pool must never submit to the same
//! pool (`ThreadPool::map` joins on a shared pending count). Pick one
//! grain per pool: the serve path fans requests and keeps heads serial
//! inside each job; the benches fan hashes.

use super::yoso::YosoAttention;
use super::{Attention, HeadTask};
use crate::lsh::{HadamardHasher, Hasher, HyperplaneHasher};
use crate::tensor::Mat;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;
use std::sync::Arc;

/// Hash rounds folded per pool task. A build-time constant — never a
/// function of the thread count — so the floating-point association of
/// the reduction, and therefore the output bytes, do not change when the
/// engine scales. 4 keeps transient memory at m/4 accumulators while
/// still exposing 8-way parallelism for the paper's m = 32.
pub const HASH_CHUNK: usize = 4;

/// A thread-count-agnostic executor: `threads == 1` runs inline with no
/// pool, `threads > 1` owns a `ThreadPool`. Clones share the same pool.
#[derive(Clone)]
pub struct Engine {
    pool: Option<Arc<ThreadPool>>,
    threads: usize,
}

impl Engine {
    /// Inline executor — no pool, no threads, same results.
    pub fn serial() -> Engine {
        Engine { pool: None, threads: 1 }
    }

    /// Pool-backed executor. `threads == 0` resolves to the number of
    /// available cores; `<= 1` degrades to the serial engine.
    pub fn new(threads: usize) -> Engine {
        let t = if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            threads
        };
        if t <= 1 {
            Engine::serial()
        } else {
            Engine { pool: Some(Arc::new(ThreadPool::new(t))), threads: t }
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Order-preserving map over owned items: pool when present, inline
    /// otherwise. Results are positionally identical either way.
    fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        match &self.pool {
            Some(pool) => pool.map(items, f),
            None => items.into_iter().map(f).collect(),
        }
    }

    /// Raw (unnormalized) YOSO forward with hash rounds fanned across the
    /// pool in fixed-size chunks. Bit-identical for every thread count
    /// with the same `rng`: the chunk layout and both summation orders
    /// (hashes ascending within a chunk, chunks ascending in the final
    /// reduction) are constants, independent of `threads`.
    pub fn forward_yoso_raw(
        &self,
        att: &YosoAttention,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        rng: &Rng,
    ) -> Mat {
        let d = q.cols;
        assert_eq!(k.cols, d);
        assert_eq!(v.rows, k.rows);
        let nq = q.rows;
        let dv = v.cols;
        let qn = Arc::new(q.unit_rows());
        let kn = Arc::new(k.unit_rows());
        let vv = Arc::new(v.clone());
        let (tau, m, fast) = (att.tau, att.m, att.fast_hash);
        let base = rng.clone();
        let n_chunks = (m + HASH_CHUNK - 1) / HASH_CHUNK;
        let chunks = self.map((0..n_chunks).collect::<Vec<usize>>(), move |c| {
            let lo = c * HASH_CHUNK;
            let hi = ((c + 1) * HASH_CHUNK).min(m);
            let mut acc = Mat::zeros(qn.rows, vv.cols);
            for h in lo..hi {
                let mut hrng = base.fold_in(h as u64);
                let partial = hash_round(&qn, &kn, &vv, tau, fast, &mut hrng);
                for (o, s) in acc.data.iter_mut().zip(&partial.data) {
                    *o += s;
                }
            }
            acc
        });
        let mut out = Mat::zeros(nq, dv);
        let inv_m = 1.0 / m as f32;
        for chunk in &chunks {
            for (o, s) in out.data.iter_mut().zip(&chunk.data) {
                *o += inv_m * s;
            }
        }
        out
    }

    /// Analytic auxiliary-memory model of `forward_yoso_raw` — the
    /// engine trades the serial path's single reused table for chunk
    /// accumulators plus one live (table + partial) per running worker.
    pub fn workspace_bytes(&self, att: &YosoAttention, n: usize, d: usize) -> usize {
        let n_chunks = (att.m + HASH_CHUNK - 1) / HASH_CHUNK;
        let live_tasks = self.threads.min(n_chunks);
        n_chunks * n * d * 4
            + live_tasks * (((1 << att.tau) * d + n * d) * 4 + 2 * n * 4)
    }

    /// YOSO forward honoring the variant's `normalize` flag (N-YOSO).
    pub fn forward_yoso(
        &self,
        att: &YosoAttention,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        rng: &Rng,
    ) -> Mat {
        let mut out = self.forward_yoso_raw(att, q, k, v, rng);
        if att.normalize {
            out.l2_normalize_rows();
        }
        out
    }
}

/// One hash round: per-round hasher from `rng`, scatter `V` into this
/// round's own bucket table, gather per query. Returns the (nq, dv)
/// partial sum (the caller applies 1/m during reduction).
fn hash_round(qn: &Mat, kn: &Mat, v: &Mat, tau: usize, fast: bool, rng: &mut Rng) -> Mat {
    let d = qn.cols;
    let (cq, ck) = if fast {
        let hasher = HadamardHasher::new(rng, 1, d, tau);
        (hasher.hash_all(qn), hasher.hash_all(kn))
    } else {
        let hasher = HyperplaneHasher::new(rng, 1, d, tau);
        (hasher.hash_all(qn), hasher.hash_all(kn))
    };
    let dv = v.cols;
    let n_buckets = 1usize << tau;
    let mut table = vec![0.0f32; n_buckets * dv];
    for j in 0..kn.rows {
        let b = ck[j] as usize;
        let dst = &mut table[b * dv..(b + 1) * dv];
        for (t, s) in dst.iter_mut().zip(v.row(j)) {
            *t += s;
        }
    }
    let mut partial = Mat::zeros(qn.rows, dv);
    for i in 0..qn.rows {
        let b = cq[i] as usize;
        let src = &table[b * dv..(b + 1) * dv];
        for (o, s) in partial.row_mut(i).iter_mut().zip(src) {
            *o += s;
        }
    }
    partial
}

/// Batched multi-head attention: fans independent head tasks across the
/// engine. Matches `Attention::forward_batch`'s serial default
/// bit-for-bit (same per-head `fold_in` streams, order-preserving map).
pub struct MultiHeadAttention {
    engine: Engine,
}

impl MultiHeadAttention {
    pub fn new(engine: Engine) -> MultiHeadAttention {
        MultiHeadAttention { engine }
    }

    /// Pool-free instance (for use inside jobs already on a pool).
    pub fn serial() -> MultiHeadAttention {
        MultiHeadAttention::new(Engine::serial())
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Forward every head; result `i` corresponds to `heads[i]`.
    pub fn forward_batch(
        &self,
        attn: &Arc<dyn Attention>,
        heads: Vec<HeadTask>,
        rng: &Rng,
    ) -> Vec<Mat> {
        let attn = Arc::clone(attn);
        let base = rng.clone();
        let items: Vec<(usize, HeadTask)> = heads.into_iter().enumerate().collect();
        self.engine.map(items, move |(i, h)| {
            let mut r = base.fold_in(i as u64);
            attn.forward(&h.q, &h.k, &h.v, &mut r)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::by_name;
    use crate::attention::yoso::YosoE;
    use crate::util::stats::radians_between;

    fn setup(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        (q, k, v)
    }

    fn bits_equal(a: &Mat, b: &Mat) -> bool {
        a.rows == b.rows
            && a.cols == b.cols
            && a.data
                .iter()
                .zip(&b.data)
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn thread_count_resolution() {
        assert_eq!(Engine::serial().threads(), 1);
        assert!(Engine::new(0).threads() >= 1);
        assert_eq!(Engine::new(1).threads(), 1);
        assert_eq!(Engine::new(3).threads(), 3);
    }

    #[test]
    fn parallel_yoso_bit_identical_to_serial() {
        let (q, k, v) = setup(96, 32, 11);
        let att = YosoAttention::new(6, 16, false);
        let rng = Rng::new(77);
        let serial = Engine::serial().forward_yoso(&att, &q, &k, &v, &rng);
        for threads in [2usize, 4, 7] {
            let par = Engine::new(threads).forward_yoso(&att, &q, &k, &v, &rng);
            assert!(bits_equal(&serial, &par), "threads={threads}");
        }
        // explicit reference: manual chunked fold, no Engine involved
        let mut reference = Mat::zeros(q.rows, v.cols);
        let qn = q.unit_rows();
        let kn = k.unit_rows();
        let inv_m = 1.0 / att.m as f32;
        let n_chunks = (att.m + HASH_CHUNK - 1) / HASH_CHUNK;
        for c in 0..n_chunks {
            let mut acc = Mat::zeros(q.rows, v.cols);
            for h in c * HASH_CHUNK..((c + 1) * HASH_CHUNK).min(att.m) {
                let mut hrng = rng.fold_in(h as u64);
                let partial =
                    hash_round(&qn, &kn, &v, att.tau, false, &mut hrng);
                for (o, s) in acc.data.iter_mut().zip(&partial.data) {
                    *o += s;
                }
            }
            for (o, s) in reference.data.iter_mut().zip(&acc.data) {
                *o += inv_m * s;
            }
        }
        reference.l2_normalize_rows();
        assert!(bits_equal(&serial, &reference));
    }

    #[test]
    fn fast_hash_round_parallel_matches_serial() {
        let (q, k, v) = setup(64, 32, 3);
        let att = YosoAttention::new(5, 12, true);
        let rng = Rng::new(9);
        let serial = Engine::serial().forward_yoso(&att, &q, &k, &v, &rng);
        let par = Engine::new(4).forward_yoso(&att, &q, &k, &v, &rng);
        assert!(bits_equal(&serial, &par));
        assert!(serial.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn engine_estimate_converges_to_expectation() {
        // engine streams differ from the legacy single-stream draw, but
        // the estimator is the same: it must still approach YOSO-E.
        let (q, k, v) = setup(48, 16, 0);
        let mut e = YosoE { tau: 4 }.forward_raw(&q, &k, &v);
        e.l2_normalize_rows();
        let att = YosoAttention::new(4, 256, false);
        let y = Engine::new(2).forward_yoso(&att, &q, &k, &v, &Rng::new(5));
        let err: f64 = (0..q.rows)
            .map(|i| radians_between(y.row(i), e.row(i)))
            .sum::<f64>()
            / q.rows as f64;
        assert!(err < 0.3, "engine estimate too far from expectation: {err}");
    }

    #[test]
    fn multihead_matches_trait_default() {
        let mut rng = Rng::new(21);
        let heads: Vec<HeadTask> = (0..6)
            .map(|_| {
                let q = Mat::randn(40, 32, 1.0, &mut rng).unit_rows();
                let k = Mat::randn(40, 32, 1.0, &mut rng).unit_rows();
                let v = Mat::randn(40, 32, 1.0, &mut rng);
                HeadTask { q, k, v }
            })
            .collect();
        let base = Rng::new(1234);
        for name in ["yoso_8", "softmax", "reformer", "performer"] {
            let mut ctor = Rng::new(2);
            let attn: Arc<dyn Attention> = Arc::from(by_name(name, &mut ctor, 32));
            let serial = attn.forward_batch(&heads, &base);
            let mh = MultiHeadAttention::new(Engine::new(3));
            let par = mh.forward_batch(&attn, heads.clone(), &base);
            assert_eq!(serial.len(), par.len());
            for (a, b) in serial.iter().zip(&par) {
                assert!(bits_equal(a, b), "{name}");
            }
        }
    }
}
