//! Parallel multi-head YOSO forward engine.
//!
//! Two independent grains of parallelism over the work-stealing
//! `util::ThreadPool`, both deterministic for a given caller seed:
//!
//! * **Per-hash** (`Engine::forward_yoso`): the `m` hash rounds of one
//!   YOSO forward are embarrassingly parallel. Round `h` draws its
//!   projections from the fixed stream `rng.fold_in(h)` and scatters into
//!   its *own* bucket table. Rounds are grouped into chunk-sized tasks
//!   (hashes summed ascending within a chunk, chunk accumulators reduced
//!   ascending on the caller thread), bounding transient memory at
//!   m/chunk accumulators.
//! * **Per-head** (`MultiHeadAttention::forward_batch`): independent
//!   `[batch, heads] x (Q, K, V)` tasks fan across the pool; head `i`
//!   draws from `rng.fold_in(i)`, matching the serial default
//!   `Attention::forward_batch` bit-for-bit.
//!
//! # Chunking policy and the determinism contract
//!
//! How many hash rounds fold into one task is a [`ChunkPolicy`]:
//!
//! * [`ChunkPolicy::fixed`]`(4)` — the default; bit-compatible with the
//!   original fixed `HASH_CHUNK = 4` layout.
//! * [`ChunkPolicy::adaptive`]`(width)` — sizes chunks from the policy
//!   inputs (m, the per-round workload n·d, and the *declared* target
//!   width): enough chunks to keep `width` workers busy with stealing
//!   slack, but each chunk large enough to amortize per-task scheduling
//!   overhead when rounds are tiny.
//!
//! The invariant both policies keep: **task layout is a function of the
//! policy inputs only — never of the executing pool's thread count**.
//! The adaptive policy's `width` is a constant captured at construction
//! (snapshot the core count into it if you want that), so every term and
//! every association of the floating-point reduction is fixed once the
//! policy is fixed, and output bytes are identical at every thread
//! count, including the serial engine, under either scheduler. Changing
//! the *policy* (or its resolved chunk size) legitimately changes the
//! reduction association and therefore the bytes; changing *threads*
//! never does. The 1-vs-N property tests assert this for both policies.
//!
//! Note: the engine's per-hash streams differ from the *legacy*
//! single-stream draw order of `YosoAttention::forward` (one hasher
//! object drawing all m rounds from one sequence). Both are unbiased
//! samples of the same estimator; "bit-identical" guarantees here relate
//! engine runs at different thread counts, not engine vs legacy.
//!
//! Deadlock rule: jobs running *on* a pool must never submit to the same
//! pool (`ThreadPool::map`/`run_batch` block on batch completion). Pick
//! one grain per pool: the serve path fans requests and keeps heads
//! serial inside each job; the benches fan hashes.

use super::kernel::{self, KernelVariant};
use super::yoso::YosoAttention;
use super::{Attention, HeadTask};
use crate::lsh::{HadamardHasher, Hasher, HyperplaneHasher};
use crate::tensor::Mat;
use crate::util::threadpool::{ChannelPool, ThreadPool};
use crate::util::Rng;
use std::sync::Arc;

/// Default hash rounds folded per pool task (`ChunkPolicy::fixed(4)`).
/// 4 keeps transient memory at m/4 accumulators while still exposing
/// 8-way parallelism for the paper's m = 32.
pub const HASH_CHUNK: usize = 4;

/// How many hash rounds fold into one pool task. The resolved chunk size
/// is a pure function of `(m, n, d)` and the policy's own constants —
/// never of the executing thread count — so the engine's output bytes
/// depend on the policy, not on how many workers ran it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkPolicy {
    /// Fold exactly `chunk` rounds per task (layout of the original
    /// fixed `HASH_CHUNK` engine when `chunk == 4`).
    Fixed { chunk: usize },
    /// Size chunks from m, the per-round workload n·d, and a *declared*
    /// target width. `width` is a policy constant captured at
    /// construction, not the executing pool's thread count.
    Adaptive { width: usize },
}

impl ChunkPolicy {
    /// Fixed chunking; `fixed(4)` is the bit-compatible default.
    pub fn fixed(chunk: usize) -> ChunkPolicy {
        ChunkPolicy::Fixed { chunk: chunk.max(1) }
    }

    /// Adaptive chunking targeting `width` workers (0 snapshots the
    /// machine's core count — at construction, once; the value is a
    /// constant of the policy from then on).
    pub fn adaptive(width: usize) -> ChunkPolicy {
        let w = if width == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            width
        };
        ChunkPolicy::Adaptive { width: w.max(1) }
    }

    /// Resolve the rounds-per-task for a forward with `m` hash rounds
    /// over an (n, d) query block.
    pub fn chunk_size(&self, m: usize, n: usize, d: usize) -> usize {
        let m = m.max(1);
        match *self {
            // .max(1): the `fixed()` ctor clamps, but the variant fields
            // are public — a literal `Fixed { chunk: 0 }` must not turn
            // into a divide-by-zero in the chunk-count ceil
            ChunkPolicy::Fixed { chunk } => chunk.max(1),
            ChunkPolicy::Adaptive { width } => {
                // ~3 tasks per declared worker: enough slack for the
                // stealing scheduler to rebalance without shrinking
                // tasks to scheduling noise
                let target_tasks = (3 * width).clamp(1, m);
                let mut chunk = (m + target_tasks - 1) / target_tasks;
                // tiny rounds amortize poorly: fold more of them per
                // task as the per-round n·d work shrinks
                let round_work = n.saturating_mul(d);
                let floor = if round_work < (1 << 14) {
                    4
                } else if round_work < (1 << 17) {
                    2
                } else {
                    1
                };
                chunk = chunk.max(floor);
                chunk.min(m)
            }
        }
    }

    /// Stable label for CSV columns and logs, e.g. `fixed4`, `adaptive8`.
    pub fn label(&self) -> String {
        match *self {
            ChunkPolicy::Fixed { chunk } => format!("fixed{chunk}"),
            ChunkPolicy::Adaptive { width } => format!("adaptive{width}"),
        }
    }
}

impl Default for ChunkPolicy {
    fn default() -> Self {
        ChunkPolicy::Fixed { chunk: HASH_CHUNK }
    }
}

/// The executor behind an `Engine`: inline, the work-stealing pool, or
/// the legacy channel pool (kept for scheduler A/B benchmarking).
#[derive(Clone)]
enum Exec {
    Inline,
    Stealing(Arc<ThreadPool>),
    Channel(Arc<ChannelPool>),
}

/// A thread-count-agnostic executor: `threads == 1` runs inline with no
/// pool, `threads > 1` owns a pool. Clones share the same pool. The
/// chunk policy rides the engine so every consumer (benches, encoder,
/// serve config) resolves task layout the same way.
#[derive(Clone)]
pub struct Engine {
    exec: Exec,
    threads: usize,
    chunk: ChunkPolicy,
}

impl Engine {
    /// Inline executor — no pool, no threads, same results.
    pub fn serial() -> Engine {
        Engine { exec: Exec::Inline, threads: 1, chunk: ChunkPolicy::default() }
    }

    /// Work-stealing pool executor. `threads == 0` resolves to the
    /// number of available cores; `<= 1` degrades to the serial engine.
    pub fn new(threads: usize) -> Engine {
        Engine::with_policy(threads, ChunkPolicy::default())
    }

    /// Work-stealing executor with an explicit chunk policy.
    pub fn with_policy(threads: usize, chunk: ChunkPolicy) -> Engine {
        let t = Engine::resolve(threads);
        if t <= 1 {
            Engine { exec: Exec::Inline, threads: 1, chunk }
        } else {
            Engine {
                exec: Exec::Stealing(Arc::new(ThreadPool::new(t))),
                threads: t,
                chunk,
            }
        }
    }

    /// Legacy channel-per-job scheduler (`util::ChannelPool`) behind the
    /// same API and determinism contract — the fig7 scheduler baseline.
    /// Not for production paths; the stealing pool is strictly cheaper.
    pub fn new_channel(threads: usize) -> Engine {
        Engine::new_channel_with(threads, ChunkPolicy::default())
    }

    /// Channel-scheduler engine with an explicit chunk policy.
    pub fn new_channel_with(threads: usize, chunk: ChunkPolicy) -> Engine {
        let t = Engine::resolve(threads);
        if t <= 1 {
            Engine { exec: Exec::Inline, threads: 1, chunk }
        } else {
            Engine {
                exec: Exec::Channel(Arc::new(ChannelPool::new(t))),
                threads: t,
                chunk,
            }
        }
    }

    fn resolve(threads: usize) -> usize {
        if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The engine's chunk policy (task-layout contract).
    pub fn chunk_policy(&self) -> ChunkPolicy {
        self.chunk
    }

    /// Replace the chunk policy (builder style). Changing the policy may
    /// change output bytes (different reduction association); changing
    /// threads never does.
    pub fn with_chunk_policy(mut self, chunk: ChunkPolicy) -> Engine {
        self.chunk = chunk;
        self
    }

    /// Scheduler label for CSV columns: `serial`, `steal`, or `chan`.
    pub fn sched_label(&self) -> &'static str {
        match self.exec {
            Exec::Inline => "serial",
            Exec::Stealing(_) => "steal",
            Exec::Channel(_) => "chan",
        }
    }

    /// Order-preserving map over owned items: pool when present, inline
    /// otherwise. Results are positionally identical either way.
    fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        match &self.exec {
            Exec::Inline => items.into_iter().map(f).collect(),
            Exec::Stealing(pool) => pool.map(items, f),
            Exec::Channel(pool) => pool.map(items, f),
        }
    }

    /// Raw (unnormalized) YOSO forward with hash rounds fanned across the
    /// pool in policy-sized chunks. Bit-identical for every thread count
    /// with the same `rng` and policy: the chunk layout and both
    /// summation orders (hashes ascending within a chunk, chunks
    /// ascending in the final reduction) are functions of the policy
    /// inputs, independent of `threads` and of the scheduler.
    pub fn forward_yoso_raw(
        &self,
        att: &YosoAttention,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        rng: &Rng,
    ) -> Mat {
        let d = q.cols;
        assert_eq!(k.cols, d);
        assert_eq!(v.rows, k.rows);
        let nq = q.rows;
        let dv = v.cols;
        let qn = Arc::new(q.unit_rows());
        let kn = Arc::new(k.unit_rows());
        let vv = Arc::new(v.clone());
        let (tau, m, fast) = (att.tau, att.m, att.fast_hash);
        let variant = att.kernel;
        let base = rng.clone();
        let chunk = self.chunk.chunk_size(m, nq, d);
        let n_chunks = (m + chunk - 1) / chunk;
        let chunks = self.map((0..n_chunks).collect::<Vec<usize>>(), move |c| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(m);
            let mut acc = Mat::zeros(qn.rows, vv.cols);
            match variant {
                KernelVariant::Seed => {
                    for h in lo..hi {
                        let mut hrng = base.fold_in(h as u64);
                        let partial = hash_round(&qn, &kn, &vv, tau, fast, &mut hrng);
                        for (o, s) in acc.data.iter_mut().zip(&partial.data) {
                            *o += s;
                        }
                    }
                }
                // fused rounds run out of the worker's thread-local
                // arena: workers are long-lived, so steady-state rounds
                // allocate only the chunk accumulator above. `acc +=
                // table[b]` equals the seed partial-then-add bit-for-bit
                // (the partial is 0 + table[b]).
                KernelVariant::Fused => kernel::with_arena(|arena| {
                    for h in lo..hi {
                        let mut hrng = base.fold_in(h as u64);
                        kernel::fused_round(
                            arena, &qn, &kn, &vv, tau, fast, &mut hrng, &mut acc,
                        );
                    }
                }),
            }
            acc
        });
        let mut out = Mat::zeros(nq, dv);
        let inv_m = 1.0 / m as f32;
        for chunk_acc in &chunks {
            for (o, s) in out.data.iter_mut().zip(&chunk_acc.data) {
                *o += inv_m * s;
            }
        }
        out
    }

    /// Analytic auxiliary-memory model of `forward_yoso_raw` — the
    /// engine trades the serial path's single reused table for chunk
    /// accumulators plus one live (table + partial) per running worker.
    /// Resolves the same `ChunkPolicy` as the forward, so fixed and
    /// adaptive layouts report their own accumulator counts.
    pub fn workspace_bytes(&self, att: &YosoAttention, n: usize, d: usize) -> usize {
        let chunk = self.chunk.chunk_size(att.m, n, d);
        let n_chunks = (att.m + chunk - 1) / chunk;
        let live_tasks = self.threads.min(n_chunks);
        let per_task = match att.kernel {
            // reused round table + (nq, dv) partial + 1-hash codes
            KernelVariant::Seed => ((1 << att.tau) * d + n * d) * 4 + 2 * n * 4,
            // per-worker arena round: table + per-hash codes + bucket
            // sort + hash scratch; gathers straight into the chunk
            // accumulator, so no partial
            KernelVariant::Fused => {
                (1 << att.tau) * d * 4
                    + 2 * n * 4
                    + kernel::sort_scratch_bytes(att.tau, n)
                    + kernel::hash_scratch_bytes(att.tau, 1, att.fast_hash, n, d)
            }
        };
        n_chunks * n * d * 4 + live_tasks * per_task
    }

    /// YOSO forward honoring the variant's `normalize` flag (N-YOSO).
    pub fn forward_yoso(
        &self,
        att: &YosoAttention,
        q: &Mat,
        k: &Mat,
        v: &Mat,
        rng: &Rng,
    ) -> Mat {
        let mut out = self.forward_yoso_raw(att, q, k, v, rng);
        if att.normalize {
            out.l2_normalize_rows();
        }
        out
    }
}

/// One *seed-kernel* hash round: per-round hasher from `rng`, scatter
/// `V` into this round's own bucket table, gather per query. Returns
/// the (nq, dv) partial sum (the caller applies 1/m during reduction).
/// Preserved verbatim (per-token hashing included) as the fused round's
/// A/B baseline and bit-identity reference; `kernel::fused_round` is
/// the arena-backed equivalent.
fn hash_round(qn: &Mat, kn: &Mat, v: &Mat, tau: usize, fast: bool, rng: &mut Rng) -> Mat {
    let d = qn.cols;
    let (cq, ck) = if fast {
        let hasher = HadamardHasher::new(rng, 1, d, tau);
        (hasher.hash_all(qn), hasher.hash_all(kn))
    } else {
        let hasher = HyperplaneHasher::new(rng, 1, d, tau);
        (hasher.hash_all_seed(qn), hasher.hash_all_seed(kn))
    };
    let dv = v.cols;
    let n_buckets = 1usize << tau;
    let mut table = vec![0.0f32; n_buckets * dv];
    for j in 0..kn.rows {
        let b = ck[j] as usize;
        let dst = &mut table[b * dv..(b + 1) * dv];
        for (t, s) in dst.iter_mut().zip(v.row(j)) {
            *t += s;
        }
    }
    let mut partial = Mat::zeros(qn.rows, dv);
    for i in 0..qn.rows {
        let b = cq[i] as usize;
        let src = &table[b * dv..(b + 1) * dv];
        for (o, s) in partial.row_mut(i).iter_mut().zip(src) {
            *o += s;
        }
    }
    partial
}

/// Batched multi-head attention: fans independent head tasks across the
/// engine. Matches `Attention::forward_batch`'s serial default
/// bit-for-bit (same per-head `fold_in` streams, order-preserving map).
pub struct MultiHeadAttention {
    engine: Engine,
}

impl MultiHeadAttention {
    pub fn new(engine: Engine) -> MultiHeadAttention {
        MultiHeadAttention { engine }
    }

    /// Pool-free instance (for use inside jobs already on a pool).
    pub fn serial() -> MultiHeadAttention {
        MultiHeadAttention::new(Engine::serial())
    }

    /// Pool-free instance carrying an explicit chunk policy — the CPU
    /// serve path plumbs its configured policy through here so any
    /// engine-level call (`forward_yoso`, `workspace_bytes`) made under
    /// a request resolves the layout the server was configured with.
    /// Head fan-out itself goes through the attention trait and is
    /// policy-independent.
    pub fn serial_with_policy(chunk: ChunkPolicy) -> MultiHeadAttention {
        MultiHeadAttention::new(Engine::serial().with_chunk_policy(chunk))
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The engine's chunk policy (convenience passthrough).
    pub fn chunk_policy(&self) -> ChunkPolicy {
        self.engine.chunk_policy()
    }

    /// Forward every head; result `i` corresponds to `heads[i]`.
    pub fn forward_batch(
        &self,
        attn: &Arc<dyn Attention>,
        heads: Vec<HeadTask>,
        rng: &Rng,
    ) -> Vec<Mat> {
        let attn = Arc::clone(attn);
        let base = rng.clone();
        let items: Vec<(usize, HeadTask)> = heads.into_iter().enumerate().collect();
        self.engine.map(items, move |(i, h)| {
            let mut r = base.fold_in(i as u64);
            attn.forward(&h.q, &h.k, &h.v, &mut r)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::by_name;
    use crate::attention::yoso::YosoE;
    use crate::testing::test_threads;
    use crate::util::stats::radians_between;

    fn setup(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        (q, k, v)
    }

    fn bits_equal(a: &Mat, b: &Mat) -> bool {
        a.rows == b.rows
            && a.cols == b.cols
            && a.data
                .iter()
                .zip(&b.data)
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn thread_count_resolution() {
        assert_eq!(Engine::serial().threads(), 1);
        assert!(Engine::new(0).threads() >= 1);
        assert_eq!(Engine::new(1).threads(), 1);
        assert_eq!(Engine::new(3).threads(), 3);
        assert_eq!(Engine::new_channel(3).threads(), 3);
        assert_eq!(Engine::serial().sched_label(), "serial");
        assert_eq!(Engine::new(2).sched_label(), "steal");
        assert_eq!(Engine::new_channel(2).sched_label(), "chan");
    }

    #[test]
    fn chunk_policy_resolution() {
        assert_eq!(ChunkPolicy::fixed(4).chunk_size(32, 512, 64), 4);
        assert_eq!(ChunkPolicy::fixed(0).chunk_size(32, 512, 64), 1);
        assert_eq!(ChunkPolicy::default().chunk_size(32, 512, 64), HASH_CHUNK);
        // adaptive resolves within [1, m] for any inputs
        for width in [1usize, 2, 4, 8, 64] {
            let p = ChunkPolicy::adaptive(width);
            for (m, n, d) in [(1usize, 8usize, 8usize), (8, 64, 32), (32, 512, 64),
                              (128, 4096, 64), (256, 16, 16)] {
                let c = p.chunk_size(m, n, d);
                assert!((1..=m).contains(&c), "width={width} m={m} n={n} d={d}: {c}");
            }
        }
        // big rounds + wide pools chunk finer than tiny rounds
        let wide = ChunkPolicy::adaptive(8);
        assert!(wide.chunk_size(32, 4096, 64) <= wide.chunk_size(32, 16, 16));
        assert_eq!(ChunkPolicy::fixed(4).label(), "fixed4");
        assert_eq!(ChunkPolicy::adaptive(8).label(), "adaptive8");
        assert!(ChunkPolicy::adaptive(0).chunk_size(32, 512, 64) >= 1);
    }

    #[test]
    fn parallel_yoso_bit_identical_to_serial() {
        let (q, k, v) = setup(96, 32, 11);
        let att = YosoAttention::new(6, 16, false);
        let rng = Rng::new(77);
        let serial = Engine::serial().forward_yoso(&att, &q, &k, &v, &rng);
        for threads in [2usize, 4, 7] {
            let par = Engine::new(threads).forward_yoso(&att, &q, &k, &v, &rng);
            assert!(bits_equal(&serial, &par), "threads={threads}");
        }
        // explicit reference: manual chunked fold, no Engine involved
        let mut reference = Mat::zeros(q.rows, v.cols);
        let qn = q.unit_rows();
        let kn = k.unit_rows();
        let inv_m = 1.0 / att.m as f32;
        let n_chunks = (att.m + HASH_CHUNK - 1) / HASH_CHUNK;
        for c in 0..n_chunks {
            let mut acc = Mat::zeros(q.rows, v.cols);
            for h in c * HASH_CHUNK..((c + 1) * HASH_CHUNK).min(att.m) {
                let mut hrng = rng.fold_in(h as u64);
                let partial =
                    hash_round(&qn, &kn, &v, att.tau, false, &mut hrng);
                for (o, s) in acc.data.iter_mut().zip(&partial.data) {
                    *o += s;
                }
            }
            for (o, s) in reference.data.iter_mut().zip(&acc.data) {
                *o += inv_m * s;
            }
        }
        reference.l2_normalize_rows();
        assert!(bits_equal(&serial, &reference));
    }

    #[test]
    fn adaptive_policy_bit_identical_across_thread_counts() {
        // the tentpole invariant: adaptive layout is fixed by the policy,
        // so thread count (and scheduler) remain pure wall-clock knobs
        let (q, k, v) = setup(80, 32, 5);
        let att = YosoAttention::new(6, 24, false);
        let rng = Rng::new(13);
        let policy = ChunkPolicy::adaptive(4);
        let serial = Engine::serial()
            .with_chunk_policy(policy)
            .forward_yoso(&att, &q, &k, &v, &rng);
        for threads in [2usize, 3, 8] {
            let steal = Engine::with_policy(threads, policy)
                .forward_yoso(&att, &q, &k, &v, &rng);
            assert!(bits_equal(&serial, &steal), "steal threads={threads}");
            let chan = Engine::new_channel_with(threads, policy)
                .forward_yoso(&att, &q, &k, &v, &rng);
            assert!(bits_equal(&serial, &chan), "chan threads={threads}");
        }
    }

    #[test]
    fn adaptive_matches_fixed_at_resolved_chunk() {
        // when adaptive resolves to chunk size c, its bytes must equal
        // Fixed(c)'s — the layout, not the policy enum, decides the sum
        let (q, k, v) = setup(64, 32, 21);
        let att = YosoAttention::new(5, 16, false);
        let rng = Rng::new(3);
        let adaptive = ChunkPolicy::adaptive(2);
        let c = adaptive.chunk_size(att.m, q.rows, q.cols);
        let t = test_threads(4);
        let a = Engine::with_policy(t, adaptive).forward_yoso(&att, &q, &k, &v, &rng);
        let f = Engine::with_policy(t, ChunkPolicy::fixed(c))
            .forward_yoso(&att, &q, &k, &v, &rng);
        assert!(bits_equal(&a, &f), "adaptive(c={c}) != fixed({c})");
    }

    #[test]
    fn channel_engine_matches_stealing_engine() {
        let (q, k, v) = setup(64, 32, 8);
        let att = YosoAttention::new(5, 12, false);
        let rng = Rng::new(17);
        let t = test_threads(4);
        let steal = Engine::new(t).forward_yoso(&att, &q, &k, &v, &rng);
        let chan = Engine::new_channel(t).forward_yoso(&att, &q, &k, &v, &rng);
        assert!(bits_equal(&steal, &chan));
    }

    #[test]
    fn fast_hash_round_parallel_matches_serial() {
        let (q, k, v) = setup(64, 32, 3);
        let att = YosoAttention::new(5, 12, true);
        let rng = Rng::new(9);
        let serial = Engine::serial().forward_yoso(&att, &q, &k, &v, &rng);
        let par = Engine::new(test_threads(4)).forward_yoso(&att, &q, &k, &v, &rng);
        assert!(bits_equal(&serial, &par));
        assert!(serial.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn workspace_reflects_policy() {
        let att = YosoAttention::new(8, 32, false);
        let fixed = Engine::with_policy(4, ChunkPolicy::fixed(4));
        let coarse = Engine::with_policy(4, ChunkPolicy::fixed(16));
        // coarser chunks => fewer accumulators => no more workspace
        assert!(coarse.workspace_bytes(&att, 1024, 64)
            <= fixed.workspace_bytes(&att, 1024, 64));
        // adaptive stays monotone in n (the prop test sweeps this wider)
        let adaptive = Engine::with_policy(4, ChunkPolicy::adaptive(4));
        let mut prev = 0usize;
        for n in [16usize, 64, 256, 1024, 4096] {
            let ws = adaptive.workspace_bytes(&att, n, 64);
            assert!(ws >= prev, "adaptive workspace shrank at n={n}");
            prev = ws;
        }
    }

    #[test]
    fn engine_estimate_converges_to_expectation() {
        // engine streams differ from the legacy single-stream draw, but
        // the estimator is the same: it must still approach YOSO-E.
        let (q, k, v) = setup(48, 16, 0);
        let mut e = YosoE { tau: 4 }.forward_raw(&q, &k, &v);
        e.l2_normalize_rows();
        let att = YosoAttention::new(4, 256, false);
        let y = Engine::new(2).forward_yoso(&att, &q, &k, &v, &Rng::new(5));
        let err: f64 = (0..q.rows)
            .map(|i| radians_between(y.row(i), e.row(i)))
            .sum::<f64>()
            / q.rows as f64;
        assert!(err < 0.3, "engine estimate too far from expectation: {err}");
    }

    #[test]
    fn multihead_matches_trait_default() {
        let mut rng = Rng::new(21);
        let heads: Vec<HeadTask> = (0..6)
            .map(|_| {
                let q = Mat::randn(40, 32, 1.0, &mut rng).unit_rows();
                let k = Mat::randn(40, 32, 1.0, &mut rng).unit_rows();
                let v = Mat::randn(40, 32, 1.0, &mut rng);
                HeadTask { q, k, v }
            })
            .collect();
        let base = Rng::new(1234);
        for name in ["yoso_8", "softmax", "reformer", "performer"] {
            let mut ctor = Rng::new(2);
            let attn: Arc<dyn Attention> = Arc::from(by_name(name, &mut ctor, 32));
            let serial = attn.forward_batch(&heads, &base);
            let mh = MultiHeadAttention::new(Engine::new(test_threads(3)));
            let par = mh.forward_batch(&attn, heads.clone(), &base);
            assert_eq!(serial.len(), par.len());
            for (a, b) in serial.iter().zip(&par) {
                assert!(bits_equal(a, b), "{name}");
            }
        }
    }
}
