"""LSH hash-code computation (L1).

Two projection families, both producing packed hyperplane codes
``codes[h, i] in [0, 2^tau)`` for ``m`` independent hashes over ``n``
unit-norm vectors:

* **Gaussian** — the textbook SimHash: ``sign(x @ R_h)`` with
  ``R_h ~ N(0, 1)^{d x tau}``. Reference implementation, exact collision
  probability ``(1 - theta/pi)^tau``.

* **Fast Hadamard (Andoni et al., 2015)** — the paper's speed-up: replace
  the dense ``d x tau`` projection with the ``H D3 H D2 H D1`` construction
  (``H`` the Walsh–Hadamard transform, ``D_i`` random sign diagonals), cost
  ``O(tau log2 d)`` per token instead of ``O(tau d)``.

Both are provided as pure-jnp functions and as Pallas kernels. The Pallas
kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls) and tile the token axis with a ``BlockSpec`` so the VMEM
working set stays at one (block_n, d) tile plus the code tile —
see DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# All Pallas kernels in this repo run in interpreter mode: the CPU PJRT
# client executes plain HLO; real-TPU lowering would emit Mosaic calls.
INTERPRET = True

DEFAULT_BLOCK_N = 128


# ---------------------------------------------------------------------------
# Parameter sampling (build-time; the Rust coordinator passes only a seed)
# ---------------------------------------------------------------------------

def gaussian_rotations(key: jax.Array, m: int, d: int, tau: int) -> jnp.ndarray:
    """(m, d, tau) i.i.d. standard-normal hyperplanes."""
    return jax.random.normal(key, (m, d, tau), dtype=jnp.float32)


def hadamard_signs(key: jax.Array, m: int, d: int,
                   rounds: int = 3) -> jnp.ndarray:
    """(m, rounds, d) Rademacher sign diagonals for the HD_r construction."""
    bits = jax.random.bernoulli(key, 0.5, (m, rounds, d))
    return jnp.where(bits, 1.0, -1.0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Pure-jnp reference
# ---------------------------------------------------------------------------

def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a trailing tau-axis of {0,1} into int32 codes."""
    tau = bits.shape[-1]
    powers = (2 ** jnp.arange(tau, dtype=jnp.int32))
    return jnp.sum(bits.astype(jnp.int32) * powers, axis=-1)


def hash_codes(x: jnp.ndarray, rotations: jnp.ndarray) -> jnp.ndarray:
    """Packed Gaussian SimHash codes.

    x: (n, d); rotations: (m, d, tau). Returns (m, n) int32.
    """
    proj = jnp.einsum("nd,mdt->mnt", x, rotations)
    return pack_bits(proj >= 0.0)


def hadamard_transform(x: jnp.ndarray) -> jnp.ndarray:
    """Walsh–Hadamard transform along the last axis (power-of-two length).

    Unnormalized butterfly; only signs are consumed so scaling is irrelevant.
    """
    d = x.shape[-1]
    assert d & (d - 1) == 0, f"Hadamard needs power-of-two dim, got {d}"
    h = 1
    while h < d:
        x = x.reshape(x.shape[:-1] + (d // (2 * h), 2, h))
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2)
        x = x.reshape(x.shape[:-3] + (d,))
        h *= 2
    return x


def hash_codes_hadamard(x: jnp.ndarray, signs: jnp.ndarray,
                        tau: int) -> jnp.ndarray:
    """Packed codes via the fast H D_r ... H D_1 projection.

    x: (n, d); signs: (m, rounds, d). Takes the first ``tau`` coordinates'
    signs of the rotated vector as the hyperplane bits. Returns (m, n) int32.
    """
    def one_hash(s):  # s: (rounds, d)
        y = x
        for r in range(s.shape[0]):
            y = hadamard_transform(y * s[r][None, :])
        return pack_bits(y[:, :tau] >= 0.0)

    return jax.vmap(one_hash)(signs)


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------

def _gaussian_code_kernel(x_ref, rot_ref, out_ref, *, tau: int):
    """One (hash, token-block) grid cell: project, threshold, pack.

    x_ref:   (block_n, d)   VMEM tile of inputs
    rot_ref: (1, d, tau)    this hash's hyperplanes (broadcast over blocks)
    out_ref: (1, block_n)   packed int32 codes
    """
    proj = jnp.dot(x_ref[...], rot_ref[0],
                   preferred_element_type=jnp.float32)     # (block_n, tau)
    bits = (proj >= 0.0).astype(jnp.int32)
    powers = (2 ** jax.lax.iota(jnp.int32, tau))[None, :]  # (1, tau)
    out_ref[0, :] = jnp.sum(bits * powers, axis=-1)


def hash_codes_pallas(x: jnp.ndarray, rotations: jnp.ndarray,
                      block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """Pallas Gaussian SimHash: grid (m, n/block_n); codes (m, n) int32.

    The rotation tile is re-fetched per hash (index_map ignores the token
    axis), so VMEM holds one (block_n, d) input tile + one (d, tau) rotation
    tile + one (1, block_n) code tile at a time.
    """
    n, d = x.shape
    m, _, tau = rotations.shape
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    grid = (m, n // block_n)
    return pl.pallas_call(
        functools.partial(_gaussian_code_kernel, tau=tau),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda h, i: (i, 0)),
            pl.BlockSpec((1, d, tau), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda h, i: (h, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=INTERPRET,
    )(x, rotations.reshape(m, d, tau))


def _hadamard_code_kernel(x_ref, signs_ref, out_ref, *, tau: int, d: int,
                          rounds: int):
    """Butterfly Hadamard stages entirely in the VMEM tile, then pack.

    x_ref:     (block_n, d)
    signs_ref: (1, rounds, d)
    out_ref:   (1, block_n)
    """
    y = x_ref[...]
    for r in range(rounds):
        y = y * signs_ref[0, r, :][None, :]
        # In-register butterfly: log2(d) stages of stride-h add/sub.
        h = 1
        while h < d:
            y = y.reshape(-1, d // (2 * h), 2, h)
            a = y[:, :, 0, :]
            b = y[:, :, 1, :]
            y = jnp.stack([a + b, a - b], axis=-2).reshape(-1, d)
            h *= 2
    bits = (y[:, :tau] >= 0.0).astype(jnp.int32)
    powers = (2 ** jax.lax.iota(jnp.int32, tau))[None, :]
    out_ref[0, :] = jnp.sum(bits * powers, axis=-1)


def hash_codes_hadamard_pallas(x: jnp.ndarray, signs: jnp.ndarray, tau: int,
                               block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """Pallas fast-Hadamard SimHash. x: (n, d); signs: (m, rounds, d)."""
    n, d = x.shape
    m, rounds, _ = signs.shape
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    grid = (m, n // block_n)
    return pl.pallas_call(
        functools.partial(_hadamard_code_kernel, tau=tau, d=d, rounds=rounds),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda h, i: (i, 0)),
            pl.BlockSpec((1, rounds, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda h, i: (h, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=INTERPRET,
    )(x, signs)
