"""YOSO-Attention backward kernels and the trainable custom-VJP op (L1).

Backward estimators from the paper, all linear in n:

* ``nabla_V  ~= (1/m) sum_h B_h(K, Q) G`` — the forward kernel with the
  query/key roles swapped (Sec. 3.3).

* ``nabla_Q  ~= [(G V^T) . (tau/2) B-hat] K`` — Eq. (4), the numerically
  safe lower bound of the collision-probability derivative. Decomposed
  per the paper into d LSH-Bernoulli-sampling subroutines, which in the
  one-hot-matmul formulation becomes *outer-product* bucket tables:

      T_h[c] = sum_{j: f_h(K_j)=c}  V_j (x) K_j         (2^tau, dv, d)
      nabla_Q_i = tau/(2m) sum_h sum_l G_il T_h[f_h(Q_i)][l, :]

  ``nabla_K`` is the mirror image with (G (x) Q) tables gathered at key
  codes — the same two kernels serve both directions.

VMEM note: one outer-product table block is 2^tau * dv * d floats
(tau=8, dv=d=64 -> 4 MiB), within the ~16 MiB VMEM budget; the paper's
"reuse the table d^2 times" memory trick corresponds to shrinking the
block along the flattened (dv*d) axis, which BlockSpec supports — we keep
the full slab since it fits.

The ``make_yoso_attention`` factory assembles a ``jax.custom_vjp`` op:
sampled Bernoulli forward + the estimators above as the VJP, so an entire
train step (L2) lowers into one HLO module with no quadratic tensor
anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .hashing import INTERPRET, DEFAULT_BLOCK_N, hash_codes
from .yoso import build_tables_pallas, gather_pallas, _onehot


# ---------------------------------------------------------------------------
# nabla_V — forward kernels, roles swapped
# ---------------------------------------------------------------------------

def grad_v_pallas(g: jnp.ndarray, codes_q: jnp.ndarray, codes_k: jnp.ndarray,
                  tau: int, block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """nabla_V = (1/m) sum_h onehot(codes_k)_h [onehot(codes_q)_h^T G]."""
    tables = build_tables_pallas(g, codes_q, tau, block_n)
    return gather_pallas(tables, codes_k, block_n)


# ---------------------------------------------------------------------------
# nabla_Q / nabla_K — outer-product bucket tables
# ---------------------------------------------------------------------------

def _grad_table_kernel(codes_ref, a_ref, b_ref, table_ref, *,
                       n_buckets: int):
    """Accumulate T[c] += sum_j 1[codes_j = c] a_j (x) b_j.

    codes_ref: (1, block_n) int32; a_ref: (block_n, da); b_ref: (block_n, db)
    table_ref: (1, n_buckets, da * db), resident across token tiles.
    """
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        table_ref[...] = jnp.zeros_like(table_ref)

    bn, da = a_ref.shape
    db = b_ref.shape[1]
    outer = (a_ref[...][:, :, None] * b_ref[...][:, None, :])
    outer = outer.reshape(bn, da * db)
    oh = _onehot(codes_ref[0, :], n_buckets)
    table_ref[0, :, :] += jnp.dot(oh.T, outer,
                                  preferred_element_type=jnp.float32)


def build_outer_tables_pallas(a: jnp.ndarray, b: jnp.ndarray,
                              codes: jnp.ndarray, tau: int,
                              block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """(m, 2^tau, da*db) tables of sum of outer products a_j (x) b_j."""
    n, da = a.shape
    db = b.shape[1]
    m = codes.shape[0]
    n_buckets = 1 << tau
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    return pl.pallas_call(
        functools.partial(_grad_table_kernel, n_buckets=n_buckets),
        grid=(m, n // block_n),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda h, i: (h, i)),
            pl.BlockSpec((block_n, da), lambda h, i: (i, 0)),
            pl.BlockSpec((block_n, db), lambda h, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_buckets, da * db),
                               lambda h, i: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_buckets, da * db), jnp.float32),
        interpret=INTERPRET,
    )(codes, a, b)


def _grad_gather_kernel(codes_ref, w_ref, table_ref, out_ref, *,
                        n_buckets: int, da: int, db: int, scale: float):
    """out_i += scale * sum_l w_il T[f(x_i)][l, :].

    codes_ref: (1, block_n); w_ref: (block_n, da);
    table_ref: (1, n_buckets, da*db); out_ref: (block_n, db).
    """
    h = pl.program_id(1)

    @pl.when(h == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bn = w_ref.shape[0]
    oh = _onehot(codes_ref[0, :], n_buckets)
    rows = jnp.dot(oh, table_ref[0, :, :],
                   preferred_element_type=jnp.float32)     # (bn, da*db)
    rows = rows.reshape(bn, da, db)
    out_ref[...] += scale * jnp.einsum("il,ild->id", w_ref[...], rows)


def gather_outer_tables_pallas(tables: jnp.ndarray, w: jnp.ndarray,
                               codes: jnp.ndarray, da: int, db: int,
                               scale: float,
                               block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """(n, db) gradient rows from outer-product tables. w: (n, da)."""
    m, n_buckets, _ = tables.shape
    n = codes.shape[1]
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    return pl.pallas_call(
        functools.partial(_grad_gather_kernel, n_buckets=n_buckets,
                          da=da, db=db, scale=scale),
        grid=(n // block_n, m),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, h: (h, i)),
            pl.BlockSpec((block_n, da), lambda i, h: (i, 0)),
            pl.BlockSpec((1, n_buckets, da * db), lambda i, h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, db), lambda i, h: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, db), jnp.float32),
        interpret=INTERPRET,
    )(codes, w, tables)


def grad_q_pallas(k: jnp.ndarray, v: jnp.ndarray, g: jnp.ndarray,
                  codes_q: jnp.ndarray, codes_k: jnp.ndarray, tau: int,
                  block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """Sampled Eq. (4): tables of V (x) K at key codes, gathered by G at
    query codes, scaled by tau/(2m)."""
    m = codes_q.shape[0]
    dv = v.shape[1]
    d = k.shape[1]
    tables = build_outer_tables_pallas(v, k, codes_k, tau, block_n)
    return gather_outer_tables_pallas(tables, g, codes_q, dv, d,
                                      scale=0.5 * tau / m, block_n=block_n)


def grad_k_pallas(q: jnp.ndarray, v: jnp.ndarray, g: jnp.ndarray,
                  codes_q: jnp.ndarray, codes_k: jnp.ndarray, tau: int,
                  block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """Mirror of Eq. (4): tables of G (x) Q at query codes, gathered by V
    at key codes."""
    m = codes_q.shape[0]
    dv = v.shape[1]
    d = q.shape[1]
    tables = build_outer_tables_pallas(g, q, codes_q, tau, block_n)
    return gather_outer_tables_pallas(tables, v, codes_k, dv, d,
                                      scale=0.5 * tau / m, block_n=block_n)


# ---------------------------------------------------------------------------
# Trainable op: sampled forward + estimator VJP
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def make_yoso_attention(tau: int, impl: str = "jnp"):
    """Build the custom-VJP YOSO op for a given tau / implementation.

    Returns ``fn(q, k, v, rotations) -> Y`` (unnormalized B-hat V estimate;
    callers apply ``ref.l2_normalize`` so the normalization gradient is
    exact autodiff). ``rotations``: (m, d, tau) hyperplanes; the number of
    hashes m is the leading axis.

    ``impl`` selects the realization of the *identical* estimator:

    * "dense"   — materialize B-hat = mean_h 1[f_h(Q)=f_h(K)] once and use
                  plain matmuls. O(n^2 m) but tiny constants; the fastest
                  realization at the small n the CPU train-step artifacts
                  run at. This is what the fused train steps lower.
    * "scatter" — the paper's linear-in-n bucket-table algorithm via XLA
                  segment-scatter (CPU's native equivalent of Fig. 3).
    * "pallas"  — the L1 Pallas kernels (one-hot MXU contractions; the TPU
                  realization, interpret=True here).

    All three agree to float tolerance (pytest: test_kernels.py,
    test_impl_equivalence).
    """
    if impl not in ("dense", "jnp", "scatter", "pallas"):
        raise ValueError(f"unknown impl {impl!r}")
    if impl == "jnp":            # backwards-compatible alias
        impl = "scatter"

    n_buckets = 1 << tau

    def scatter_tables(x, codes):
        """(m, 2^tau, dx) bucket sums via segment-scatter, vmapped over m.

        On CPU-XLA scatter is the cheap realization of the paper's
        ``H[f(K_j)] += V_j``; the Pallas kernels realize the same table as
        one-hot MXU contractions for TPU (DESIGN.md §Hardware-Adaptation).
        """
        return jax.vmap(
            lambda c: jax.ops.segment_sum(x, c, num_segments=n_buckets)
        )(codes)

    def table_attention(x, codes_in, codes_out):
        """mean_h gather(segment_sum(x, codes_in[h]), codes_out[h])."""
        tables = scatter_tables(x, codes_in)            # (m, 2^tau, dx)
        gathered = jax.vmap(lambda t, c: t[c])(tables, codes_out)
        return jnp.mean(gathered, axis=0)

    def bhat_matrix(codes_q, codes_k):
        """mean_h 1[codes_q[h,i] == codes_k[h,j]] — (n, n) f32."""
        return jnp.mean(
            (codes_q[:, :, None] == codes_k[:, None, :]).astype(jnp.float32),
            axis=0)

    def fwd_impl(q, k, v, rotations):
        codes_q = hash_codes(q, rotations)
        codes_k = hash_codes(k, rotations)
        if impl == "pallas":
            from .yoso import yoso_sampled_pallas
            y = yoso_sampled_pallas(v, codes_q, codes_k, tau,
                                    normalize=False)
        elif impl == "dense":
            y = bhat_matrix(codes_q, codes_k) @ v
        else:
            y = table_attention(v, codes_k, codes_q)
        return y, codes_q, codes_k

    @jax.custom_vjp
    def yoso_attention(q, k, v, rotations):
        y, _, _ = fwd_impl(q, k, v, rotations)
        return y

    def vjp_fwd(q, k, v, rotations):
        y, codes_q, codes_k = fwd_impl(q, k, v, rotations)
        return y, (q, k, v, rotations, codes_q, codes_k)

    def vjp_bwd(res, g):
        q, k, v, rotations, codes_q, codes_k = res
        m = codes_q.shape[0]
        if impl == "pallas":
            dv_ = grad_v_pallas(g, codes_q, codes_k, tau)
            dq = grad_q_pallas(k, v, g, codes_q, codes_k, tau)
            dk = grad_k_pallas(q, v, g, codes_q, codes_k, tau)
        elif impl == "dense":
            bhat = bhat_matrix(codes_q, codes_k)
            dv_ = bhat.T @ g
            w = (0.5 * tau) * bhat
            dq = ((g @ v.T) * w) @ k
            dk = ((v @ g.T) * w.T) @ q
        else:
            n, d = q.shape
            dv_dim = v.shape[1]
            # nabla_V: forward with roles swapped.
            dv_ = table_attention(g, codes_q, codes_k)
            scale = 0.5 * tau / m
            # nabla_Q: outer-product tables V (x) K at key codes, gathered
            # at query codes and contracted with G (Eq. 4, sampled).
            vk = (v[:, :, None] * k[:, None, :]).reshape(n, dv_dim * d)
            t_vk = scatter_tables(vk, codes_k)          # (m, 2^tau, dv*d)
            rows = jax.vmap(lambda t, c: t[c])(t_vk, codes_q)
            rows = rows.reshape(m, n, dv_dim, d)
            dq = scale * jnp.einsum("il,hild->id", g, rows)
            # nabla_K: G (x) Q tables at query codes, gathered by V.
            gq = (g[:, :, None] * q[:, None, :]).reshape(n, dv_dim * d)
            t_gq = scatter_tables(gq, codes_q)
            rows_k = jax.vmap(lambda t, c: t[c])(t_gq, codes_k)
            rows_k = rows_k.reshape(m, n, dv_dim, d)
            dk = scale * jnp.einsum("jl,hjld->jd", v, rows_k)
        return dq, dk, dv_, jnp.zeros_like(rotations)

    yoso_attention.defvjp(vjp_fwd, vjp_bwd)
    return yoso_attention


@functools.lru_cache(maxsize=None)
def make_yoso_e_attention(tau: int, backward: str = "exact"):
    """YOSO-E (expectation) op. ``backward``:

    * "autodiff" — plain clipped autodiff through the collision probability.
    * "exact"    — Eq. (3) weighting (the *YOSO estimator's expectation).
    * "lower"    — Eq. (4) lower-bound weighting (the YOSO estimator's
                   expectation); what YOSO-E-trained models in the paper use
                   to stay consistent with the sampled backward.
    """
    if backward == "autodiff":
        def fn(q, k, v):
            return ref.yoso_e_attention(q, k, v, tau, normalize=False)
        return fn

    if backward not in ("exact", "lower"):
        raise ValueError(f"unknown backward {backward!r}")

    @jax.custom_vjp
    def yoso_e(q, k, v):
        return ref.yoso_e_attention(q, k, v, tau, normalize=False)

    def vjp_fwd(q, k, v):
        return yoso_e(q, k, v), (q, k, v)

    def vjp_bwd(res, g):
        q, k, v = res
        dv_ = ref.yoso_e_grad_v(q, k, g, tau)
        if backward == "exact":
            dq = ref.yoso_e_grad_q_exact(q, k, v, g, tau)
            dk = ref.yoso_e_grad_k_exact(q, k, v, g, tau)
        else:
            dq = ref.yoso_e_grad_q_lower_bound(q, k, v, g, tau)
            dk = ref.yoso_e_grad_k_lower_bound(q, k, v, g, tau)
        return dq, dk, dv_

    yoso_e.defvjp(vjp_fwd, vjp_bwd)
    return yoso_e
