"""Pure-jnp oracle for YOSO attention.

Everything in this module is the *mathematical definition* — quadratic,
materializing the full n x n Bernoulli / collision-probability matrices —
used as the correctness reference for the Pallas kernels in `yoso.py`,
`yoso_grad.py` and `hashing.py`, and for the YOSO-E ("infinite hashes")
model variant.

Notation follows the paper (Zeng et al., ICML 2021):

  sim      = Q K^T                       (unit-norm rows, so sim in [-1, 1])
  E[B]_ij  = (1 - arccos(sim_ij)/pi)^tau   -- collision probability of tau
                                             concatenated hyperplane hashes
  YOSO     = B(Q, K) V                   (one realization per hash)
  YOSO-E   = E[B] V                      (expectation, "infinite hashes")
  N-YOSO   = l2-normalize(YOSO)          (row-wise, replaces softmax's D_P)
"""

from __future__ import annotations

import jax.numpy as jnp

# Keep arccos away from the poles where its derivative blows up; the paper's
# backward lower bound (Eq. 4) exists precisely because of this pole.
_SIM_EPS = 1e-6


def l2_normalize(x: jnp.ndarray, axis: int = -1, eps: float = 1e-6) -> jnp.ndarray:
    """Row-wise l2 normalization; safe (value *and* gradient) at zero rows.

    A YOSO-m query that collides with no key yields an exactly-zero row;
    sqrt has an infinite derivative at 0, so the eps lives *inside* the
    square root to keep the backward pass finite.
    """
    norm = jnp.sqrt(jnp.sum(x * x, axis=axis, keepdims=True) + eps * eps)
    return x / norm


def unit_rows(x: jnp.ndarray) -> jnp.ndarray:
    """Project each row onto the unit sphere (the paper's Remark 1 via the
    simpler l2-normalization the experiments actually use)."""
    return l2_normalize(x)


def collision_probability(sim: jnp.ndarray, tau: int) -> jnp.ndarray:
    """E[B]_ij = (1 - arccos(sim)/pi)^tau for sim in [-1, 1]."""
    sim = jnp.clip(sim, -1.0 + _SIM_EPS, 1.0 - _SIM_EPS)
    return (1.0 - jnp.arccos(sim) / jnp.pi) ** tau


def collision_probability_grad(sim: jnp.ndarray, tau: int) -> jnp.ndarray:
    """d/dsim of the collision probability (Eq. 3's weight factor):

        tau * (1 - arccos(sim)/pi)^(tau-1) / (pi * sqrt(1 - sim^2))

    Diverges as |sim| -> 1; callers clip. This is the *YOSO weighting.
    """
    sim = jnp.clip(sim, -1.0 + _SIM_EPS, 1.0 - _SIM_EPS)
    base = 1.0 - jnp.arccos(sim) / jnp.pi
    return tau * base ** (tau - 1) / (jnp.pi * jnp.sqrt(1.0 - sim * sim))


def collision_probability_grad_lower_bound(sim: jnp.ndarray, tau: int) -> jnp.ndarray:
    """The paper's numerically-safe lower bound (tau/2) * E[B] used for the
    YOSO backward pass (Eq. 4)."""
    return 0.5 * tau * collision_probability(sim, tau)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def yoso_e_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     tau: int, normalize: bool = True) -> jnp.ndarray:
    """YOSO-E: expectation attention. q, k: (n, d) unit rows; v: (n, dv)."""
    weights = collision_probability(q @ k.T, tau)
    out = weights @ v
    return l2_normalize(out) if normalize else out


def bernoulli_matrix(codes_q: jnp.ndarray, codes_k: jnp.ndarray) -> jnp.ndarray:
    """Realized Bernoulli matrices from packed hash codes.

    codes_q, codes_k: (m, n) int32 — per-hash packed codes in [0, 2^tau).
    Returns (m, n, n) float32 with B[h, i, j] = 1[codes_q[h,i] == codes_k[h,j]].
    """
    return (codes_q[:, :, None] == codes_k[:, None, :]).astype(jnp.float32)


def yoso_sampled_attention(v: jnp.ndarray, codes_q: jnp.ndarray,
                           codes_k: jnp.ndarray,
                           normalize: bool = True) -> jnp.ndarray:
    """YOSO-m with explicit code realizations (naive n^2 comparison).

    Output_i = (1/m) sum_h sum_j 1[f_h(Q_i) = f_h(K_j)] V_j.
    """
    b = bernoulli_matrix(codes_q, codes_k)          # (m, n, n)
    out = jnp.mean(b @ v[None, :, :], axis=0)       # (n, dv)
    return l2_normalize(out) if normalize else out


# ---------------------------------------------------------------------------
# Backward (expectation forms — the oracle for the sampled estimators)
# ---------------------------------------------------------------------------

def yoso_e_grad_v(q: jnp.ndarray, k: jnp.ndarray, g: jnp.ndarray,
                  tau: int) -> jnp.ndarray:
    """nabla_V L = E[B(Q,K)]^T G (paper: B(K,Q) applied to the cotangent)."""
    return collision_probability(q @ k.T, tau).T @ g


def yoso_e_grad_q_lower_bound(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                              g: jnp.ndarray, tau: int) -> jnp.ndarray:
    """Eq. (4) in expectation: [(G V^T) . (tau/2) E[B]] K."""
    w = collision_probability_grad_lower_bound(q @ k.T, tau)
    return ((g @ v.T) * w) @ k


def yoso_e_grad_k_lower_bound(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                              g: jnp.ndarray, tau: int) -> jnp.ndarray:
    """Symmetric counterpart of Eq. (4) for K: [(V G^T) . (tau/2) E[B]^T] Q."""
    w = collision_probability_grad_lower_bound(q @ k.T, tau)
    return ((v @ g.T) * w.T) @ q


def yoso_e_grad_q_exact(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        g: jnp.ndarray, tau: int) -> jnp.ndarray:
    """Eq. (3): the true (clipped) derivative weighting — the *YOSO variant."""
    w = collision_probability_grad(q @ k.T, tau)
    return ((g @ v.T) * w) @ k


def yoso_e_grad_k_exact(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        g: jnp.ndarray, tau: int) -> jnp.ndarray:
    w = collision_probability_grad(q @ k.T, tau)
    return ((v @ g.T) * w.T) @ q


# ---------------------------------------------------------------------------
# Backward (sampled forms — what the LSH-table kernels estimate)
# ---------------------------------------------------------------------------

def yoso_sampled_grad_v(g: jnp.ndarray, codes_q: jnp.ndarray,
                        codes_k: jnp.ndarray) -> jnp.ndarray:
    """nabla_V ~= (1/m) sum_h B_h^T G."""
    b = bernoulli_matrix(codes_q, codes_k)
    return jnp.mean(jnp.einsum("hij,il->hjl", b, g), axis=0)


def yoso_sampled_grad_q(k: jnp.ndarray, v: jnp.ndarray, g: jnp.ndarray,
                        codes_q: jnp.ndarray, codes_k: jnp.ndarray,
                        tau: int) -> jnp.ndarray:
    """Sampled Eq. (4): [(G V^T) . (tau/2) B-hat] K with B-hat = mean_h B_h."""
    bhat = jnp.mean(bernoulli_matrix(codes_q, codes_k), axis=0)
    return ((g @ v.T) * (0.5 * tau * bhat)) @ k


def yoso_sampled_grad_k(q: jnp.ndarray, v: jnp.ndarray, g: jnp.ndarray,
                        codes_q: jnp.ndarray, codes_k: jnp.ndarray,
                        tau: int) -> jnp.ndarray:
    bhat = jnp.mean(bernoulli_matrix(codes_q, codes_k), axis=0)
    return ((v @ g.T) * (0.5 * tau * bhat.T)) @ q


# ---------------------------------------------------------------------------
# Softmax reference (the baseline the paper approximates)
# ---------------------------------------------------------------------------

def softmax_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      scale: float | None = None) -> jnp.ndarray:
    """Standard scaled-dot-product attention; the exact baseline."""
    d = q.shape[-1]
    scale = (1.0 / jnp.sqrt(d)) if scale is None else scale
    p = jnp.asarray(q @ k.T) * scale
    p = p - jnp.max(p, axis=-1, keepdims=True)
    w = jnp.exp(p)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w @ v
