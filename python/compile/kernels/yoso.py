"""YOSO-Attention forward kernels (L1, Pallas).

The paper's GPU algorithm (Fig. 3) scatter-adds each value ``V_j`` into a
hash-table bucket ``H[f(K_j)]`` and gathers ``Y_i = H[f(Q_i)]`` — atomics
plus gathers. TPUs have no efficient scatter, so the Pallas port
re-expresses both steps as MXU contractions over one-hot code matrices
(DESIGN.md §Hardware-Adaptation):

    table  H_h = onehot(f_h(K))^T V          (2^tau, dv) = (2^tau, n)(n, dv)
    output Y   = 1/m sum_h onehot(f_h(Q)) H_h

Equality of *packed* codes is exactly the conjunction of tau hyperplane
collisions, so the Bernoulli realizations are exact, and the cost is
data-independent (no bucket-skew pathology — the same property Remark 3
claims for the sum-table trick on GPU).

Both kernels tile the token axis with BlockSpec; the bucket table lives in
VMEM for the duration of one hash (2^tau x dv floats; tau <= 9, dv <= 64
=> at most 128 KiB) and is accumulated across token tiles via revisited
output blocks (the revisit axis is the innermost grid axis, so the block
stays resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .hashing import INTERPRET, DEFAULT_BLOCK_N


def _onehot(codes: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """(n,) int32 -> (n, n_buckets) f32 one-hot, via broadcast compare."""
    iota = jax.lax.iota(jnp.int32, n_buckets)[None, :]
    return (codes[:, None] == iota).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Stage 1: bucket tables  H[h] = onehot(codes_k[h])^T V
# ---------------------------------------------------------------------------

def _table_kernel(codes_ref, v_ref, table_ref, *, n_buckets: int):
    """Grid (m, n/block_n), token axis innermost: accumulate one hash table.

    codes_ref: (1, block_n) int32   this hash's key codes for the tile
    v_ref:     (block_n, dv)        value tile
    table_ref: (1, n_buckets, dv)   resident accumulator for hash h
    """
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        table_ref[...] = jnp.zeros_like(table_ref)

    oh = _onehot(codes_ref[0, :], n_buckets)                  # (bn, 2^tau)
    table_ref[0, :, :] += jnp.dot(oh.T, v_ref[...],
                                  preferred_element_type=jnp.float32)


def build_tables_pallas(v: jnp.ndarray, codes_k: jnp.ndarray, tau: int,
                        block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """(m, 2^tau, dv) value-sum tables from key codes. v: (n, dv)."""
    n, dv = v.shape
    m = codes_k.shape[0]
    n_buckets = 1 << tau
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    return pl.pallas_call(
        functools.partial(_table_kernel, n_buckets=n_buckets),
        grid=(m, n // block_n),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda h, i: (h, i)),
            pl.BlockSpec((block_n, dv), lambda h, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_buckets, dv), lambda h, i: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n_buckets, dv), jnp.float32),
        interpret=INTERPRET,
    )(codes_k, v)


# ---------------------------------------------------------------------------
# Stage 2: query gather  Y = 1/m sum_h onehot(codes_q[h]) H[h]
# ---------------------------------------------------------------------------

def _gather_kernel(codes_ref, table_ref, out_ref, *, n_buckets: int,
                   inv_m: float):
    """Grid (n/block_n, m), hash axis innermost: one output tile resident.

    codes_ref: (1, block_n) int32   this hash's query codes for the tile
    table_ref: (1, n_buckets, dv)   hash h's bucket table
    out_ref:   (block_n, dv)        resident output accumulator
    """
    h = pl.program_id(1)

    @pl.when(h == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    oh = _onehot(codes_ref[0, :], n_buckets)                  # (bn, 2^tau)
    out_ref[...] += inv_m * jnp.dot(oh, table_ref[0, :, :],
                                    preferred_element_type=jnp.float32)


def gather_pallas(tables: jnp.ndarray, codes_q: jnp.ndarray,
                  block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """Y (n, dv) from tables (m, 2^tau, dv) and query codes (m, n)."""
    m, n_buckets, dv = tables.shape
    n = codes_q.shape[1]
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    return pl.pallas_call(
        functools.partial(_gather_kernel, n_buckets=n_buckets,
                          inv_m=1.0 / m),
        grid=(n // block_n, m),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, h: (h, i)),
            pl.BlockSpec((1, n_buckets, dv), lambda i, h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, dv), lambda i, h: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dv), jnp.float32),
        interpret=INTERPRET,
    )(codes_q, tables)


def yoso_sampled_pallas(v: jnp.ndarray, codes_q: jnp.ndarray,
                        codes_k: jnp.ndarray, tau: int,
                        normalize: bool = True,
                        block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """YOSO-m forward: B(Q,K) V estimated from m code realizations.

    v: (n, dv); codes_q, codes_k: (m, n) int32 packed codes.
    Linear in n: O(n m dv) time, O(m 2^tau dv) table memory.
    """
    tables = build_tables_pallas(v, codes_k, tau, block_n)
    out = gather_pallas(tables, codes_q, block_n)
    return ref.l2_normalize(out) if normalize else out


# ---------------------------------------------------------------------------
# YOSO-E (expectation) — quadratic but exact, blocked over both token axes
# ---------------------------------------------------------------------------

def _yoso_e_kernel(q_ref, k_ref, v_ref, out_ref, *, tau: int):
    """Grid (n/bn_q, n/bn_k), key axis innermost.

    q_ref: (bn_q, d); k_ref: (bn_k, d); v_ref: (bn_k, dv);
    out_ref: (bn_q, dv) resident accumulator across key tiles.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    sim = jnp.dot(q_ref[...], k_ref[...].T,
                  preferred_element_type=jnp.float32)
    sim = jnp.clip(sim, -1.0 + 1e-6, 1.0 - 1e-6)
    w = (1.0 - jnp.arccos(sim) / jnp.pi) ** tau
    out_ref[...] += jnp.dot(w, v_ref[...],
                            preferred_element_type=jnp.float32)


def yoso_e_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, tau: int,
                  normalize: bool = True,
                  block_n: int = DEFAULT_BLOCK_N) -> jnp.ndarray:
    """Expectation attention E[B(Q,K)] V, tiled like flash-attention."""
    n, d = q.shape
    dv = v.shape[1]
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n, n // block_n)
    out = pl.pallas_call(
        functools.partial(_yoso_e_kernel, tau=tau),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, dv), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, dv), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dv), jnp.float32),
        interpret=INTERPRET,
    )(q, k, v)
    return ref.l2_normalize(out) if normalize else out
