"""L1: Pallas kernels for YOSO attention (hashing, forward, backward) and
the pure-jnp oracle (`ref`)."""

from . import hashing, ref, yoso, yoso_grad  # noqa: F401
