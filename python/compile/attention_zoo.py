"""L2 attention zoo: YOSO (all variants) + every baseline in the paper.

Each attention function maps per-head tensors ``q, k, v: (n, dh)`` to an
output ``(n, dh)`` and is differentiable (YOSO through its custom-VJP
estimators, the rest through autodiff). ``multi_head`` vmaps them over
heads and the model vmaps over the batch.

Variants (paper §4.2 baselines, with the model-specific hyperparameters
the paper lists): Nyströmformer (landmarks), Longformer (sliding window),
Linformer (learned projections), Reformer (LSH bucket attention),
Performer (FAVOR+ features), Linear Transformer (elu+1), plus "none".
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.hashing import gaussian_rotations
from .kernels.yoso_grad import make_yoso_attention, make_yoso_e_attention


class AttnConfig(NamedTuple):
    """Static attention hyperparameters (baked into each artifact)."""
    kind: str = "softmax"      # softmax|none|yoso|yoso_e|linformer|performer|
                               # linear|longformer|reformer|nystrom
    tau: int = 8               # hyperplanes per hash (YOSO / Reformer)
    n_hashes: int = 16         # m — hashes averaged (YOSO / Reformer rounds)
    backward: str = "lower"    # lower = YOSO (Eq.4) | exact = *YOSO (Eq.3)
    conv_size: int = 0         # depthwise conv residual (YOSO-C); 0 = off
    linformer_k: int = 64      # projected length
    performer_features: int = 64
    window: int = 32           # longformer one-sided window
    landmarks: int = 16        # nystromformer
    impl: str = "dense"          # yoso sampling impl: jnp | pallas


def softmax_attention(q, k, v, cfg: AttnConfig, key):
    return ref.softmax_attention(q, k, v)


def none_attention(q, k, v, cfg: AttnConfig, key):
    """No token mixing — the LRA "None" reference row."""
    return v


def yoso_attention(q, k, v, cfg: AttnConfig, key):
    """YOSO-m: sampled Bernoulli attention with m = cfg.n_hashes hashes."""
    qn = ref.unit_rows(q)
    kn = ref.unit_rows(k)
    rot = gaussian_rotations(key, cfg.n_hashes, q.shape[-1], cfg.tau)
    fn = make_yoso_attention(cfg.tau, cfg.impl)
    out = fn(qn, kn, v, rot)
    if cfg.backward == "exact":
        # *YOSO: forward uses the same samples; the Eq.(3) correction is
        # applied as the difference of the expectation backwards (exact
        # minus lower), so gradients follow the true derivative weighting
        # while the forward stays the sampled estimate.
        e_exact = make_yoso_e_attention(cfg.tau, "exact")
        e_lower = make_yoso_e_attention(cfg.tau, "lower")
        correction = e_exact(qn, kn, v) - e_lower(qn, kn, v)
        out = out + correction - jax.lax.stop_gradient(correction)
    return ref.l2_normalize(out)


def yoso_e_attention(q, k, v, cfg: AttnConfig, key):
    """YOSO-E: expectation ("infinite hashes"); backward per cfg.backward."""
    qn = ref.unit_rows(q)
    kn = ref.unit_rows(k)
    fn = make_yoso_e_attention(cfg.tau, cfg.backward)
    return ref.l2_normalize(fn(qn, kn, v))


def linear_attention(q, k, v, cfg: AttnConfig, key):
    """Linear Transformer (Katharopoulos et al.): phi(x) = elu(x) + 1."""
    phi_q = jax.nn.elu(q) + 1.0
    phi_k = jax.nn.elu(k) + 1.0
    kv = phi_k.T @ v                                  # (dh, dv)
    z = phi_q @ jnp.sum(phi_k, axis=0, keepdims=True).T  # (n, 1)
    return (phi_q @ kv) / jnp.maximum(z, 1e-6)


def performer_attention(q, k, v, cfg: AttnConfig, key):
    """Performer FAVOR+ positive softmax features (Choromanski et al.)."""
    d = q.shape[-1]
    r = cfg.performer_features
    w = jax.random.normal(key, (r, d), dtype=jnp.float32)
    scale = d ** -0.25
    qs, ks = q * scale, k * scale

    def phi(x):
        proj = x @ w.T                                 # (n, r)
        sq = 0.5 * jnp.sum(x * x, axis=-1, keepdims=True)
        # subtract max for stability (row-wise constant cancels in the ratio)
        return jnp.exp(proj - sq - jnp.max(proj - sq, axis=-1, keepdims=True)
                       ) / jnp.sqrt(r)

    phi_q, phi_k = phi(qs), phi(ks)
    kv = phi_k.T @ v
    z = phi_q @ jnp.sum(phi_k, axis=0, keepdims=True).T
    return (phi_q @ kv) / jnp.maximum(z, 1e-6)


def linformer_attention(q, k, v, cfg: AttnConfig, key, proj_e=None,
                        proj_f=None):
    """Linformer: learned (n -> k) projections of keys and values."""
    assert proj_e is not None and proj_f is not None
    k_proj = proj_e.T @ k                              # (kproj, dh)
    v_proj = proj_f.T @ v
    return ref.softmax_attention(q, k_proj, v_proj)


def longformer_attention(q, k, v, cfg: AttnConfig, key):
    """Sliding-window attention (banded-mask formulation).

    The paper's Longformer baseline uses window = 512 at seq 512, i.e. full
    attention; we expose the window as a hyperparameter. The banded-mask
    realization is O(n^2) compute on this substrate but numerically
    identical to the windowed kernel; the Rust L3 library implements the
    true O(n*w) version for the efficiency study.
    """
    n, d = q.shape
    scores = (q @ k.T) / jnp.sqrt(d)
    idx = jnp.arange(n)
    band = jnp.abs(idx[:, None] - idx[None, :]) <= cfg.window
    scores = jnp.where(band, scores, -1e9)
    w = jax.nn.softmax(scores, axis=-1)
    return w @ v


def reformer_attention(q, k, v, cfg: AttnConfig, key):
    """Reformer-style LSH attention: softmax restricted to colliding
    buckets (union over rounds), realized as a collision mask.

    Reformer shares q = k (unit); we hash the normalized vectors with the
    same hyperplane family as YOSO. Mask-based realization is O(n^2) on
    this substrate (see longformer note); the Rust library implements the
    bucketed O(n log n) version.
    """
    n, d = q.shape
    rounds = max(2, min(cfg.n_hashes, 4))
    rot = gaussian_rotations(key, rounds, d, cfg.tau)
    qn, kn = ref.unit_rows(q), ref.unit_rows(k)
    from .kernels.hashing import hash_codes
    cq = hash_codes(qn, rot)                           # (rounds, n)
    ck = hash_codes(kn, rot)
    collide = jnp.any(cq[:, :, None] == ck[:, None, :], axis=0)
    eye = jnp.eye(n, dtype=bool)
    mask = collide | eye
    scores = (q @ k.T) / jnp.sqrt(d)
    scores = jnp.where(mask, scores, -1e9)
    w = jax.nn.softmax(scores, axis=-1)
    return w @ v


def nystrom_attention(q, k, v, cfg: AttnConfig, key):
    """Nyströmformer: landmark attention with iterative pseudo-inverse."""
    n, d = q.shape
    l = cfg.landmarks
    assert n % l == 0, (n, l)
    scale = 1.0 / jnp.sqrt(d)
    q_l = jnp.mean(q.reshape(l, n // l, d), axis=1)    # segment-mean landmarks
    k_l = jnp.mean(k.reshape(l, n // l, d), axis=1)

    f = jax.nn.softmax(q @ k_l.T * scale, axis=-1)     # (n, l)
    a = jax.nn.softmax(q_l @ k_l.T * scale, axis=-1)   # (l, l)
    b = jax.nn.softmax(q_l @ k.T * scale, axis=-1)     # (l, n)

    # Newton–Schulz pseudo-inverse (6 iterations, as in Xiong et al.):
    # z <- 0.25 z (13 I - az (15 I - az (7 I - az))), fixed point az = I.
    z = a.T / (jnp.max(jnp.sum(jnp.abs(a), axis=0)) *
               jnp.max(jnp.sum(jnp.abs(a), axis=1)))
    eye = jnp.eye(l)
    for _ in range(6):
        az = a @ z
        z = 0.25 * z @ (13.0 * eye - az @ (15.0 * eye - az @ (7.0 * eye - az)))
    return f @ (z @ (b @ v))


_ZOO = {
    "softmax": softmax_attention,
    "none": none_attention,
    "yoso": yoso_attention,
    "yoso_e": yoso_e_attention,
    "linear": linear_attention,
    "performer": performer_attention,
    "longformer": longformer_attention,
    "reformer": reformer_attention,
    "nystrom": nystrom_attention,
}


def attention_fn(cfg: AttnConfig):
    """Resolve the per-head attention callable for a config."""
    if cfg.kind == "linformer":
        return linformer_attention
    try:
        return _ZOO[cfg.kind]
    except KeyError:
        raise ValueError(f"unknown attention kind {cfg.kind!r}") from None


def needs_linformer_params(cfg: AttnConfig) -> bool:
    return cfg.kind == "linformer"


def depthwise_conv_residual(v_heads: jnp.ndarray,
                            kernel: jnp.ndarray) -> jnp.ndarray:
    """YOSO-C / Nyströmformer-style depthwise conv on values.

    v_heads: (h, n, dh); kernel: (h, conv_size). Causal-symmetric (SAME)
    depthwise convolution along the token axis, one filter per head.
    """
    h, n, dh = v_heads.shape

    def conv_one(vh, ker):                             # (n, dh), (cs,)
        return jax.vmap(
            lambda col: jnp.convolve(col, ker, mode="same"),
            in_axes=1, out_axes=1)(vh)

    return jax.vmap(conv_one)(v_heads, kernel)
