"""L1 performance analysis: VMEM footprint + MXU utilization estimates.

interpret=True gives no TPU wallclock, so the kernel performance targets
(DESIGN.md / EXPERIMENTS.md §Perf L1) are *structural*: per-block VMEM
working set from the BlockSpecs, and MXU utilization estimated from the
contraction shapes against the 128x128 systolic array. This module
computes those numbers from the same parameters the kernels use, so the
claims are reproducible:

    cd python && python -m compile.analysis        # prints + JSON
"""

from __future__ import annotations

import dataclasses
import json
import sys

MXU_LANES = 128          # systolic array dimension
VMEM_BUDGET = 16 << 20   # ~16 MiB per core
F32 = 4


@dataclasses.dataclass
class KernelEstimate:
    name: str
    vmem_bytes: int
    mxu_utilization: float
    flops_per_block: int
    notes: str

    def to_dict(self):
        return dataclasses.asdict(self)


def _mxu_util(m: int, k: int, n: int) -> float:
    """Utilization of a (m,k)@(k,n) matmul on a 128x128 MXU: fraction of
    lanes occupied by the contraction and output tiles, averaged over the
    k-loop (padding waste when dims < 128)."""
    def occ(dim):
        return min(dim, MXU_LANES) / MXU_LANES
    return occ(m) * occ(n) * occ(min(k, MXU_LANES)) ** 0  # k streams; m,n pad


def forward_table_kernel(block_n: int, d: int, tau: int) -> KernelEstimate:
    """yoso.py::_table_kernel — H += onehot(codes)^T V per (hash, tile)."""
    n_buckets = 1 << tau
    vmem = (block_n * 1 * F32          # codes tile (int32)
            + block_n * d * F32        # value tile
            + n_buckets * d * F32      # resident table
            + block_n * n_buckets * F32)  # onehot intermediate
    # contraction: (n_buckets, block_n) @ (block_n, d)
    util = _mxu_util(n_buckets, block_n, d)
    flops = 2 * n_buckets * block_n * d
    return KernelEstimate(
        name=f"yoso_fwd_table(bn={block_n},d={d},tau={tau})",
        vmem_bytes=vmem,
        mxu_utilization=util,
        flops_per_block=flops,
        notes="scatter realized as one-hot MXU contraction; cost "
              "data-independent (Remark 3)",
    )


def forward_gather_kernel(block_n: int, d: int, tau: int) -> KernelEstimate:
    """yoso.py::_gather_kernel — Y += onehot(codes) H per (tile, hash)."""
    n_buckets = 1 << tau
    vmem = (block_n * F32
            + n_buckets * d * F32
            + block_n * d * F32
            + block_n * n_buckets * F32)
    util = _mxu_util(block_n, n_buckets, d)
    flops = 2 * block_n * n_buckets * d
    return KernelEstimate(
        name=f"yoso_fwd_gather(bn={block_n},d={d},tau={tau})",
        vmem_bytes=vmem,
        mxu_utilization=util,
        flops_per_block=flops,
        notes="gather realized as one-hot MXU contraction",
    )


def backward_outer_table_kernel(block_n: int, d: int, dv: int,
                                tau: int) -> KernelEstimate:
    """yoso_grad.py::_grad_table_kernel — T += onehot^T (V (x) K)."""
    n_buckets = 1 << tau
    vmem = (block_n * F32
            + block_n * (d + dv) * F32
            + block_n * dv * d * F32        # outer-product tile
            + n_buckets * dv * d * F32)     # resident table slab
    util = _mxu_util(n_buckets, block_n, dv * d)
    flops = 2 * n_buckets * block_n * dv * d
    return KernelEstimate(
        name=f"yoso_bwd_table(bn={block_n},d={d},dv={dv},tau={tau})",
        vmem_bytes=vmem,
        mxu_utilization=util,
        flops_per_block=flops,
        notes="Eq.(4) outer-product tables; shrink the dv*d block axis "
              "via BlockSpec if the slab exceeds budget",
    )


def analyze(block_n: int = 128, d: int = 64, tau: int = 8) -> dict:
    kernels = [
        forward_table_kernel(block_n, d, tau),
        forward_gather_kernel(block_n, d, tau),
        backward_outer_table_kernel(block_n, d, d, tau),
    ]
    report = {
        "params": {"block_n": block_n, "d": d, "tau": tau,
                   "vmem_budget_bytes": VMEM_BUDGET},
        "kernels": [k.to_dict() for k in kernels],
        "all_within_vmem": all(k.vmem_bytes <= VMEM_BUDGET for k in kernels),
    }
    return report


def main() -> None:
    report = analyze()
    for k in report["kernels"]:
        print(f"{k['name']:48s} VMEM {k['vmem_bytes']/1024:9.1f} KiB  "
              f"MXU {k['mxu_utilization']:.2f}  "
              f"{k['flops_per_block']/1e6:7.2f} MFLOP/block",
              file=sys.stderr)
    print(f"within 16 MiB VMEM budget: {report['all_within_vmem']}",
          file=sys.stderr)
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
