"""L2: BERT-style transformer encoder with pluggable attention (JAX).

Pure-functional model: parameters are an *ordered* list of named arrays so
the Rust coordinator can marshal them positionally (the order is recorded
in the artifact manifest). The model calls the L1 kernels through
`attention_zoo`, and `train_step` fuses forward + backward + AdamW into a
single jittable function that `aot.py` lowers to one HLO module.

Tasks:
  * pretrain — MLM + SOP (the paper's §4.1 setup, ALBERT-style SOP)
  * cls      — single-sequence classification (LRA-style, GLUE-style)

Batch conventions (all int32 unless noted):
  pretrain: input_ids (b, n), segment_ids (b, n), mlm_labels (b, n)
            [-1 = unmasked], sop_labels (b,)
  cls:      input_ids (b, n), segment_ids (b, n), labels (b,)
Scalars fed at runtime: step (i32), seed (i32), lr (f32).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .attention_zoo import (AttnConfig, attention_fn,
                            depthwise_conv_residual,
                            needs_linformer_params)
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 2048
    max_len: int = 128
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    d_ff: int = 512
    n_segments: int = 2
    n_classes: int = 3          # classifier head width (cls task)
    attn: AttnConfig = AttnConfig()

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the positional ABI of every artifact."""
    d, ff, n = cfg.d_model, cfg.d_ff, cfg.max_len
    specs: list[tuple[str, tuple[int, ...]]] = [
        ("tok_emb", (cfg.vocab_size, d)),
        ("pos_emb", (n, d)),
        ("seg_emb", (cfg.n_segments, d)),
        ("emb_ln_g", (d,)),
        ("emb_ln_b", (d,)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "wq", (d, d)), (p + "bq", (d,)),
            (p + "wk", (d, d)), (p + "bk", (d,)),
            (p + "wv", (d, d)), (p + "bv", (d,)),
            (p + "wo", (d, d)), (p + "bo", (d,)),
            (p + "ln1_g", (d,)), (p + "ln1_b", (d,)),
            (p + "ff1_w", (d, ff)), (p + "ff1_b", (ff,)),
            (p + "ff2_w", (ff, d)), (p + "ff2_b", (d,)),
            (p + "ln2_g", (d,)), (p + "ln2_b", (d,)),
        ]
        if needs_linformer_params(cfg.attn):
            specs += [(p + "lin_e", (n, cfg.attn.linformer_k)),
                      (p + "lin_f", (n, cfg.attn.linformer_k))]
        if cfg.attn.conv_size > 0:
            specs += [(p + "conv_k", (cfg.n_heads, cfg.attn.conv_size))]
    specs += [
        ("mlm_w", (d, d)), ("mlm_b", (d,)),
        ("mlm_ln_g", (d,)), ("mlm_ln_b", (d,)),
        ("mlm_out_b", (cfg.vocab_size,)),       # decoder ties tok_emb
        ("pool_w", (d, d)), ("pool_b", (d,)),
        ("sop_w", (d, 2)), ("sop_b", (2,)),
        ("cls_w", (d, cfg.n_classes)), ("cls_b", (cfg.n_classes,)),
    ]
    return specs


def init_params(key: jax.Array, cfg: ModelConfig) -> list[jnp.ndarray]:
    """Truncated-normal(0.02) matrices, zero biases, unit LN gains."""
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        short = name.split(".")[-1]
        if short.endswith("_g") or short in ("ln1_g", "ln2_g"):
            params.append(jnp.ones(shape, jnp.float32))
        elif short.startswith("b") or short.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        elif short == "conv_k":
            # identity-ish depthwise kernel: small noise around a center tap
            k = 0.02 * jax.random.normal(sub, shape, jnp.float32)
            params.append(k.at[:, shape[1] // 2].add(1.0))
        else:
            params.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
    return params


def params_dict(cfg: ModelConfig, flat: list[jnp.ndarray]) -> dict:
    names = [n for n, _ in param_specs(cfg)]
    assert len(names) == len(flat), (len(names), len(flat))
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# Modules
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def multi_head_attention(p: dict, prefix: str, cfg: ModelConfig,
                         x: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    """x: (n, d_model) -> (n, d_model). vmaps the zoo fn over heads."""
    n, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = (x @ p[prefix + "wq"] + p[prefix + "bq"]).reshape(n, h, dh)
    k = (x @ p[prefix + "wk"] + p[prefix + "bk"]).reshape(n, h, dh)
    v = (x @ p[prefix + "wv"] + p[prefix + "bv"]).reshape(n, h, dh)
    q, k, v = (t.transpose(1, 0, 2) for t in (q, k, v))   # (h, n, dh)

    fn = attention_fn(cfg.attn)
    keys = jax.random.split(key, h)
    if needs_linformer_params(cfg.attn):
        e, f = p[prefix + "lin_e"], p[prefix + "lin_f"]
        out = jax.vmap(lambda qh, kh, vh, kk: fn(qh, kh, vh, cfg.attn, kk,
                                                 proj_e=e, proj_f=f)
                       )(q, k, v, keys)
    else:
        out = jax.vmap(lambda qh, kh, vh, kk: fn(qh, kh, vh, cfg.attn, kk)
                       )(q, k, v, keys)

    if cfg.attn.conv_size > 0:
        out = out + depthwise_conv_residual(v, p[prefix + "conv_k"])

    out = out.transpose(1, 0, 2).reshape(n, d)
    return out @ p[prefix + "wo"] + p[prefix + "bo"]


def encoder(p: dict, cfg: ModelConfig, input_ids: jnp.ndarray,
            segment_ids: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    """One sequence (n,) -> hidden states (n, d_model). Post-LN BERT."""
    n = input_ids.shape[0]
    x = (p["tok_emb"][input_ids]
         + p["pos_emb"][:n]
         + p["seg_emb"][segment_ids])
    x = layer_norm(x, p["emb_ln_g"], p["emb_ln_b"])
    for i in range(cfg.n_layers):
        prefix = f"layer{i}."
        key, sub = jax.random.split(key)
        a = multi_head_attention(p, prefix, cfg, x, sub)
        x = layer_norm(x + a, p[prefix + "ln1_g"], p[prefix + "ln1_b"])
        hidden = jax.nn.gelu(x @ p[prefix + "ff1_w"] + p[prefix + "ff1_b"])
        f = hidden @ p[prefix + "ff2_w"] + p[prefix + "ff2_b"]
        x = layer_norm(x + f, p[prefix + "ln2_g"], p[prefix + "ln2_b"])
    return x


def mlm_logits(p: dict, hidden: jnp.ndarray) -> jnp.ndarray:
    """BERT MLM head with tied decoder: (n, d) -> (n, vocab)."""
    t = jax.nn.gelu(hidden @ p["mlm_w"] + p["mlm_b"])
    t = layer_norm(t, p["mlm_ln_g"], p["mlm_ln_b"])
    return t @ p["tok_emb"].T + p["mlm_out_b"]


def pooled(p: dict, hidden: jnp.ndarray) -> jnp.ndarray:
    """[CLS] pooler: tanh dense on the first token."""
    return jnp.tanh(hidden[0] @ p["pool_w"] + p["pool_b"])


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------

def _log_softmax(x):
    x = x - jnp.max(x, axis=-1, keepdims=True)
    return x - jnp.log(jnp.sum(jnp.exp(x), axis=-1, keepdims=True))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  weights: jnp.ndarray):
    """Weighted token CE. logits (..., c); labels (...); weights (...)."""
    logp = _log_softmax(logits)
    picked = jnp.take_along_axis(logp, labels[..., None].clip(0), axis=-1)
    losses = -picked[..., 0] * weights
    total = jnp.sum(weights)
    return jnp.sum(losses) / jnp.maximum(total, 1.0), total


def pretrain_losses(p: dict, cfg: ModelConfig, batch: dict, key: jax.Array):
    """Batched MLM + SOP loss and metrics. Returns (loss, metrics[8])."""
    b = batch["input_ids"].shape[0]
    keys = jax.random.split(key, b)
    hidden = jax.vmap(lambda ids, seg, kk: encoder(p, cfg, ids, seg, kk)
                      )(batch["input_ids"], batch["segment_ids"], keys)
    logits = jax.vmap(lambda hh: mlm_logits(p, hh))(hidden)   # (b, n, vocab)
    labels = batch["mlm_labels"]
    weights = (labels >= 0).astype(jnp.float32)
    mlm_loss, n_masked = cross_entropy(logits, labels, weights)
    mlm_correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == labels.clip(0)) * weights)

    pool = jax.vmap(lambda hh: pooled(p, hh))(hidden)          # (b, d)
    sop_logits = pool @ p["sop_w"] + p["sop_b"]                # (b, 2)
    sop_loss, _ = cross_entropy(sop_logits, batch["sop_labels"],
                                jnp.ones((b,), jnp.float32))
    sop_correct = jnp.sum(
        (jnp.argmax(sop_logits, axis=-1) == batch["sop_labels"]
         ).astype(jnp.float32))

    loss = mlm_loss + sop_loss
    metrics = jnp.stack([
        loss, mlm_loss, sop_loss, mlm_correct, n_masked, sop_correct,
        jnp.float32(b), jnp.float32(0.0)])
    return loss, metrics


def cls_losses(p: dict, cfg: ModelConfig, batch: dict, key: jax.Array):
    """Batched sequence-classification loss. Returns (loss, metrics[8])."""
    b = batch["input_ids"].shape[0]
    keys = jax.random.split(key, b)
    hidden = jax.vmap(lambda ids, seg, kk: encoder(p, cfg, ids, seg, kk)
                      )(batch["input_ids"], batch["segment_ids"], keys)
    pool = jax.vmap(lambda hh: pooled(p, hh))(hidden)
    logits = pool @ p["cls_w"] + p["cls_b"]                    # (b, c)
    loss, _ = cross_entropy(logits, batch["labels"],
                            jnp.ones((b,), jnp.float32))
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == batch["labels"]
                       ).astype(jnp.float32))
    metrics = jnp.stack([
        loss, loss, jnp.float32(0.0), correct, jnp.float32(b),
        jnp.float32(0.0), jnp.float32(b), jnp.float32(0.0)])
    return loss, metrics


# ---------------------------------------------------------------------------
# AdamW + train/eval step builders
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
WEIGHT_DECAY = 0.01
WARMUP_STEPS = 100


def adamw_update(params, grads, m, v, step, lr):
    """One AdamW step over the flat param list (decay on matrices only)."""
    step_f = step.astype(jnp.float32) + 1.0
    lr_t = lr * jnp.minimum(1.0, step_f / WARMUP_STEPS)
    b1c = 1.0 - ADAM_B1 ** step_f
    b2c = 1.0 - ADAM_B2 ** step_f
    new_p, new_m, new_v = [], [], []
    for pi, gi, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * gi
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * gi * gi
        update = (mi / b1c) / (jnp.sqrt(vi / b2c) + ADAM_EPS)
        if pi.ndim >= 2:
            update = update + WEIGHT_DECAY * pi
        new_p.append(pi - lr_t * update)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def _loss_fn_for(task: str) -> Callable:
    return {"pretrain": pretrain_losses, "cls": cls_losses}[task]


def make_train_step(cfg: ModelConfig, task: str):
    """(params, m, v, *batch, step, seed, lr) -> (params', m', v', metrics).

    Flat positional signature so the HLO artifact's ABI is a plain list of
    literals — see `aot.py` and the manifest for the exact order.
    """
    loss_fn = _loss_fn_for(task)
    batch_keys = batch_spec(cfg, task)

    def train_step(params, m, v, batch_arrays, step, seed, lr):
        batch = dict(zip([k for k, _, _ in batch_keys], batch_arrays))
        key = jax.random.fold_in(jax.random.PRNGKey(0), seed)

        def scalar_loss(ps):
            p = params_dict(cfg, ps)
            loss, metrics = loss_fn(p, cfg, batch, key)
            return loss, metrics

        grads, metrics = jax.grad(scalar_loss, has_aux=True)(params)
        new_p, new_m, new_v = adamw_update(params, grads, m, v, step, lr)
        return new_p, new_m, new_v, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, task: str):
    """(params, *batch, seed) -> metrics[8]."""
    loss_fn = _loss_fn_for(task)
    batch_keys = batch_spec(cfg, task)

    def eval_step(params, batch_arrays, seed):
        batch = dict(zip([k for k, _, _ in batch_keys], batch_arrays))
        key = jax.random.fold_in(jax.random.PRNGKey(1), seed)
        p = params_dict(cfg, params)
        _, metrics = loss_fn(p, cfg, batch, key)
        return metrics

    return eval_step


def make_forward(cfg: ModelConfig, task: str):
    """Serving entrypoint: (params, input_ids, segment_ids, seed) -> logits."""
    def forward(params, input_ids, segment_ids, seed):
        p = params_dict(cfg, params)
        key = jax.random.fold_in(jax.random.PRNGKey(2), seed)
        b = input_ids.shape[0]
        keys = jax.random.split(key, b)
        hidden = jax.vmap(lambda ids, seg, kk: encoder(p, cfg, ids, seg, kk)
                          )(input_ids, segment_ids, keys)
        if task == "pretrain":
            return jax.vmap(lambda hh: mlm_logits(p, hh))(hidden)
        pool = jax.vmap(lambda hh: pooled(p, hh))(hidden)
        return pool @ p["cls_w"] + p["cls_b"]

    return forward


def batch_spec(cfg: ModelConfig, task: str,
               batch_size: int = 0) -> list[tuple[str, tuple, str]]:
    """(name, shape-with-batch-placeholder, dtype) for each batch array.

    batch_size = 0 leaves a symbolic 'B' the caller substitutes.
    """
    b, n = batch_size, cfg.max_len
    if task == "pretrain":
        return [("input_ids", (b, n), "i32"), ("segment_ids", (b, n), "i32"),
                ("mlm_labels", (b, n), "i32"), ("sop_labels", (b,), "i32")]
    if task == "cls":
        return [("input_ids", (b, n), "i32"), ("segment_ids", (b, n), "i32"),
                ("labels", (b,), "i32")]
    raise ValueError(task)
