"""AOT lowering: JAX (L2, calling L1 kernels) -> HLO text artifacts.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is a flat positional function over f32/i32 literals. The
ABI (input order, shapes, dtypes; output order) is recorded in
``artifacts/manifest.json`` which the Rust runtime parses.

Run: ``cd python && python -m compile.aot --out ../artifacts [--only REGEX]``
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .attention_zoo import AttnConfig
from .kernels import ref
from .kernels.hashing import gaussian_rotations, hash_codes
from .kernels.yoso import yoso_e_pallas, yoso_sampled_pallas
from . import model as M

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


# ---------------------------------------------------------------------------
# Model configurations (the three artifact families)
# ---------------------------------------------------------------------------

PRETRAIN_BATCH = 16
LRA_BATCH = 8

BASE_ENCODER = dict(n_layers=2, d_model=128, n_heads=2, d_ff=512)

PRETRAIN_CFG = dict(vocab_size=2048, max_len=128, n_classes=3,
                    **BASE_ENCODER)
LRA_CFG = dict(vocab_size=256, max_len=256, n_classes=10, **BASE_ENCODER)

ATTN = {
    # Table 2 variants (pretrain / GLUE family)
    "softmax":      AttnConfig(kind="softmax"),
    "yoso_e":       AttnConfig(kind="yoso_e", tau=8, backward="lower"),
    "star_yoso_e":  AttnConfig(kind="yoso_e", tau=8, backward="exact"),
    "yoso_16":      AttnConfig(kind="yoso", tau=8, n_hashes=16),
    "yoso_32":      AttnConfig(kind="yoso", tau=8, n_hashes=32),
    "yoso_64":      AttnConfig(kind="yoso", tau=8, n_hashes=64),
    "star_yoso_16": AttnConfig(kind="yoso", tau=8, n_hashes=16,
                               backward="exact"),
    "star_yoso_32": AttnConfig(kind="yoso", tau=8, n_hashes=32,
                               backward="exact"),
    "yoso_c_16":    AttnConfig(kind="yoso", tau=8, n_hashes=16, conv_size=9),
    # extra eval-time hash counts (Figure 5)
    "yoso_8":       AttnConfig(kind="yoso", tau=8, n_hashes=8),
    "yoso_128":     AttnConfig(kind="yoso", tau=8, n_hashes=128),
    # Table 3 baselines (LRA family)
    "none":         AttnConfig(kind="none"),
    "nystrom":      AttnConfig(kind="nystrom", landmarks=16),
    "longformer":   AttnConfig(kind="longformer", window=32),
    "linformer":    AttnConfig(kind="linformer", linformer_k=64),
    "reformer":     AttnConfig(kind="reformer", tau=6, n_hashes=2),
    "performer":    AttnConfig(kind="performer", performer_features=64),
    "linear":       AttnConfig(kind="linear"),
    "star_yoso_c_16": AttnConfig(kind="yoso", tau=8, n_hashes=16,
                                 conv_size=9, backward="exact"),
}

PRETRAIN_TRAIN = ["softmax", "yoso_e", "star_yoso_e", "yoso_16", "yoso_32",
                  "yoso_64", "star_yoso_16", "star_yoso_32", "yoso_c_16"]
PRETRAIN_EVAL = ["softmax", "yoso_e", "yoso_8", "yoso_16", "yoso_32",
                 "yoso_64", "yoso_128", "yoso_c_16"]
GLUE_VARIANTS = ["softmax", "yoso_e", "yoso_16", "yoso_32", "yoso_64",
                 "star_yoso_16", "star_yoso_32"]
LRA_VARIANTS = ["none", "softmax", "yoso_e", "yoso_32", "star_yoso_16",
                "yoso_c_16", "star_yoso_c_16", "nystrom", "longformer",
                "linformer", "reformer", "performer", "linear"]


def make_cfg(base: dict, attn_name: str) -> M.ModelConfig:
    return M.ModelConfig(attn=ATTN[attn_name], **base)


# ---------------------------------------------------------------------------
# Artifact builders
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Artifact:
    name: str
    kind: str                  # train_step | eval_step | forward | attention
    family: str                # pretrain | glue | lra | attn
    attention: str
    fn: object                 # callable to lower
    example_args: list         # ShapeDtypeStructs
    inputs: list               # [{name, shape, dtype}]
    outputs: list              # [{name, shape, dtype}]
    config: dict


def _dtype_str(s):
    return "f32" if s.dtype == jnp.float32 else "i32"


def spec_entry(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": _dtype_str(s)}


def train_step_artifact(family: str, base: dict, attn_name: str,
                        task: str, batch: int) -> Artifact:
    cfg = make_cfg(base, attn_name)
    specs = M.param_specs(cfg)
    n_params = len(specs)
    step_fn = M.make_train_step(cfg, task)

    def flat_fn(*args):
        p = list(args[:n_params])
        m = list(args[n_params:2 * n_params])
        v = list(args[2 * n_params:3 * n_params])
        rest = args[3 * n_params:]
        n_batch = len(M.batch_spec(cfg, task))
        batch_arrays = list(rest[:n_batch])
        step, seed, lr = rest[n_batch:]
        new_p, new_m, new_v, metrics = step_fn(p, m, v, batch_arrays,
                                               step, seed, lr)
        return (*new_p, *new_m, *new_v, metrics)

    param_structs = [f32(shape) for _, shape in specs]
    batch_structs = []
    batch_names = []
    for bname, bshape, bdtype in M.batch_spec(cfg, task, batch):
        batch_structs.append(i32(bshape) if bdtype == "i32" else f32(bshape))
        batch_names.append(bname)
    scalars = [i32(()), i32(()), f32(())]
    example = param_structs * 3 + batch_structs + scalars

    inputs = ([spec_entry(f"param:{n}", f32(s)) for n, s in specs]
              + [spec_entry(f"adam_m:{n}", f32(s)) for n, s in specs]
              + [spec_entry(f"adam_v:{n}", f32(s)) for n, s in specs]
              + [spec_entry(f"batch:{n}", s)
                 for n, s in zip(batch_names, batch_structs)]
              + [spec_entry("step", i32(())), spec_entry("seed", i32(())),
                 spec_entry("lr", f32(()))])
    outputs = ([spec_entry(f"param:{n}", f32(s)) for n, s in specs]
               + [spec_entry(f"adam_m:{n}", f32(s)) for n, s in specs]
               + [spec_entry(f"adam_v:{n}", f32(s)) for n, s in specs]
               + [spec_entry("metrics", f32((8,)))])

    return Artifact(
        name=f"train_{family}_{attn_name}", kind="train_step", family=family,
        attention=attn_name, fn=flat_fn, example_args=example,
        inputs=inputs, outputs=outputs,
        config=dict(task=task, batch=batch, n_params=n_params,
                    **{k: v for k, v in base.items()}))


def eval_step_artifact(family: str, base: dict, attn_name: str,
                       task: str, batch: int) -> Artifact:
    cfg = make_cfg(base, attn_name)
    specs = M.param_specs(cfg)
    n_params = len(specs)
    step_fn = M.make_eval_step(cfg, task)

    def flat_fn(*args):
        p = list(args[:n_params])
        rest = args[n_params:]
        n_batch = len(M.batch_spec(cfg, task))
        batch_arrays = list(rest[:n_batch])
        (seed,) = rest[n_batch:]
        return (step_fn(p, batch_arrays, seed),)

    param_structs = [f32(shape) for _, shape in specs]
    batch_structs = []
    batch_names = []
    for bname, bshape, bdtype in M.batch_spec(cfg, task, batch):
        batch_structs.append(i32(bshape) if bdtype == "i32" else f32(bshape))
        batch_names.append(bname)
    example = param_structs + batch_structs + [i32(())]

    inputs = ([spec_entry(f"param:{n}", f32(s)) for n, s in specs]
              + [spec_entry(f"batch:{n}", s)
                 for n, s in zip(batch_names, batch_structs)]
              + [spec_entry("seed", i32(()))])
    outputs = [spec_entry("metrics", f32((8,)))]

    return Artifact(
        name=f"eval_{family}_{attn_name}", kind="eval_step", family=family,
        attention=attn_name, fn=flat_fn, example_args=example,
        inputs=inputs, outputs=outputs,
        config=dict(task=task, batch=batch, n_params=n_params,
                    **{k: v for k, v in base.items()}))


def forward_artifact(family: str, base: dict, attn_name: str, task: str,
                     batch: int) -> Artifact:
    cfg = make_cfg(base, attn_name)
    specs = M.param_specs(cfg)
    n_params = len(specs)
    fwd = M.make_forward(cfg, task)

    def flat_fn(*args):
        p = list(args[:n_params])
        input_ids, segment_ids, seed = args[n_params:]
        return (fwd(p, input_ids, segment_ids, seed),)

    n = cfg.max_len
    example = ([f32(shape) for _, shape in specs]
               + [i32((batch, n)), i32((batch, n)), i32(())])
    out_shape = ((batch, n, cfg.vocab_size) if task == "pretrain"
                 else (batch, cfg.n_classes))
    inputs = ([spec_entry(f"param:{nm}", f32(s)) for nm, s in specs]
              + [spec_entry("batch:input_ids", i32((batch, n))),
                 spec_entry("batch:segment_ids", i32((batch, n))),
                 spec_entry("seed", i32(()))])
    outputs = [spec_entry("logits", f32(out_shape))]
    return Artifact(
        name=f"fwd_{family}_{attn_name}", kind="forward", family=family,
        attention=attn_name, fn=flat_fn, example_args=example,
        inputs=inputs, outputs=outputs,
        config=dict(task=task, batch=batch, n_params=n_params,
                    **{k: v for k, v in base.items()}))


def attention_op_artifact(name: str, variant: str, n: int, d: int,
                          tau: int, m: int) -> Artifact:
    """Standalone attention ops lowered *through the Pallas kernels* —
    the L1 -> HLO path the Rust runtime executes directly."""

    if variant == "softmax":
        def flat_fn(q, k, v, seed):
            return (ref.softmax_attention(q, k, v),)
    elif variant == "yoso_e_pallas":
        def flat_fn(q, k, v, seed):
            qn, kn = ref.unit_rows(q), ref.unit_rows(k)
            return (yoso_e_pallas(qn, kn, v, tau, normalize=True),)
    elif variant == "yoso_pallas":
        def flat_fn(q, k, v, seed):
            qn, kn = ref.unit_rows(q), ref.unit_rows(k)
            key = jax.random.fold_in(jax.random.PRNGKey(3), seed)
            rot = gaussian_rotations(key, m, d, tau)
            cq = hash_codes(qn, rot)
            ck = hash_codes(kn, rot)
            return (yoso_sampled_pallas(v, cq, ck, tau, normalize=True),)
    else:
        raise ValueError(variant)

    example = [f32((n, d)), f32((n, d)), f32((n, d)), i32(())]
    inputs = [spec_entry("q", f32((n, d))), spec_entry("k", f32((n, d))),
              spec_entry("v", f32((n, d))), spec_entry("seed", i32(()))]
    outputs = [spec_entry("out", f32((n, d)))]
    return Artifact(name=name, kind="attention", family="attn",
                    attention=variant, fn=flat_fn, example_args=example,
                    inputs=inputs, outputs=outputs,
                    config=dict(n=n, d=d, tau=tau, m=m))


def build_artifact_list() -> list[Artifact]:
    arts: list[Artifact] = []
    for a in PRETRAIN_TRAIN:
        arts.append(train_step_artifact("pretrain", PRETRAIN_CFG, a,
                                        "pretrain", PRETRAIN_BATCH))
    for a in PRETRAIN_EVAL:
        arts.append(eval_step_artifact("pretrain", PRETRAIN_CFG, a,
                                       "pretrain", PRETRAIN_BATCH))
    for a in GLUE_VARIANTS:
        arts.append(train_step_artifact("glue", PRETRAIN_CFG, a, "cls",
                                        PRETRAIN_BATCH))
        arts.append(eval_step_artifact("glue", PRETRAIN_CFG, a, "cls",
                                       PRETRAIN_BATCH))
    for a in LRA_VARIANTS:
        arts.append(train_step_artifact("lra", LRA_CFG, a, "cls", LRA_BATCH))
        arts.append(eval_step_artifact("lra", LRA_CFG, a, "cls", LRA_BATCH))
    # Serving path: classification forward (GLUE-shaped) + MLM forward.
    for a in ["softmax", "yoso_32"]:
        arts.append(forward_artifact("glue", PRETRAIN_CFG, a, "cls",
                                     PRETRAIN_BATCH))
    arts.append(forward_artifact("pretrain", PRETRAIN_CFG, "yoso_32",
                                 "pretrain", PRETRAIN_BATCH))
    # Pallas attention ops (n, d chosen to match LRA head dims).
    arts.append(attention_op_artifact("attn_softmax_n256", "softmax",
                                      256, 64, 8, 8))
    arts.append(attention_op_artifact("attn_yoso_e_n256", "yoso_e_pallas",
                                      256, 64, 8, 8))
    arts.append(attention_op_artifact("attn_yoso_m8_n256", "yoso_pallas",
                                      256, 64, 8, 8))
    return arts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex filter on artifact names")
    args = ap.parse_args()

    import os
    os.makedirs(args.out, exist_ok=True)

    manifest = {"artifacts": {}}
    manifest_path = os.path.join(args.out, "manifest.json")
    # Incremental: keep entries for artifacts we skip via --only.
    if os.path.exists(manifest_path):
        with open(manifest_path) as fh:
            try:
                manifest = json.load(fh)
            except json.JSONDecodeError:
                manifest = {"artifacts": {}}

    arts = build_artifact_list()
    pat = re.compile(args.only) if args.only else None
    for art in arts:
        if pat and not pat.search(art.name):
            continue
        t0 = time.time()
        # keep_unused: the manifest ABI lists every input; without it jax
        # drops parameters an artifact doesn't read (e.g. the classifier
        # head in a pretrain eval step) and the Rust side's positional
        # buffer list would mismatch.
        lowered = jax.jit(art.fn, keep_unused=True).lower(*art.example_args)
        text = to_hlo_text(lowered)
        fname = f"{art.name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as fh:
            fh.write(text)
        manifest["artifacts"][art.name] = {
            "file": fname, "kind": art.kind, "family": art.family,
            "attention": art.attention, "config": art.config,
            "inputs": art.inputs, "outputs": art.outputs,
        }
        print(f"lowered {art.name:34s} {len(text)/1e6:6.2f} MB "
              f"in {time.time()-t0:5.1f}s", file=sys.stderr)

    with open(manifest_path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"wrote {manifest_path} ({len(manifest['artifacts'])} artifacts)",
          file=sys.stderr)


if __name__ == "__main__":
    main()
