"""The §Perf L1 structural claims, as executable assertions."""

from compile import analysis


def test_paper_config_within_vmem_budget():
    report = analysis.analyze(block_n=128, d=64, tau=8)
    assert report["all_within_vmem"]
    for k in report["kernels"]:
        assert k["vmem_bytes"] <= analysis.VMEM_BUDGET, k["name"]


def test_forward_working_set_is_small():
    # DESIGN/EXPERIMENTS claim: forward table+gather tiles ~100 KiB class
    report = analysis.analyze(block_n=128, d=64, tau=8)
    fwd = [k for k in report["kernels"] if "fwd" in k["name"]]
    for k in fwd:
        assert k["vmem_bytes"] < 512 * 1024, k


def test_backward_slab_matches_design_doc():
    # 2^tau * d * d * 4 bytes = 4 MiB dominates the backward working set
    report = analysis.analyze(block_n=128, d=64, tau=8)
    bwd = next(k for k in report["kernels"] if "bwd" in k["name"])
    slab = (1 << 8) * 64 * 64 * 4
    assert bwd["vmem_bytes"] >= slab
    assert bwd["vmem_bytes"] < slab * 2


def test_mxu_utilization_meets_target():
    # >= 0.5 of matmul roofline claimed for the forward contractions at
    # d = 64 (half the 128-lane width => 0.5 on the short axis).
    report = analysis.analyze(block_n=128, d=64, tau=8)
    for k in report["kernels"]:
        if "fwd" in k["name"]:
            assert k["mxu_utilization"] >= 0.5, k


def test_estimates_scale_with_parameters():
    small = analysis.analyze(block_n=64, d=32, tau=6)
    large = analysis.analyze(block_n=128, d=64, tau=8)
    for ks, kl in zip(small["kernels"], large["kernels"]):
        assert ks["vmem_bytes"] < kl["vmem_bytes"]
        assert ks["flops_per_block"] < kl["flops_per_block"]
