"""L2 model tests: shapes, attention zoo, train-step learning, ABI."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import ATTN, LRA_CFG, PRETRAIN_CFG, make_cfg
from compile.attention_zoo import AttnConfig, attention_fn

jax.config.update("jax_platform_name", "cpu")


def rand_batch(cfg, task, b, seed=0):
    rng = np.random.default_rng(seed)
    n = cfg.max_len
    ids = rng.integers(5, cfg.vocab_size, size=(b, n)).astype(np.int32)
    seg = np.zeros((b, n), np.int32)
    if task == "pretrain":
        labels = np.where(rng.random((b, n)) < 0.15, ids, -1).astype(np.int32)
        sop = rng.integers(0, 2, size=(b,)).astype(np.int32)
        return [jnp.asarray(x) for x in (ids, seg, labels, sop)]
    labels = rng.integers(0, cfg.n_classes, size=(b,)).astype(np.int32)
    return [jnp.asarray(x) for x in (ids, seg, labels)]


@pytest.mark.parametrize("kind", ["softmax", "none", "yoso", "yoso_e",
                                  "linear", "performer", "longformer",
                                  "reformer", "nystrom"])
def test_attention_zoo_shapes_and_grads(kind):
    cfg = AttnConfig(kind=kind, tau=6, n_hashes=4, landmarks=8, window=8,
                     performer_features=16)
    fn = attention_fn(cfg)
    n, d = 32, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (n, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    out = fn(q, k, v, cfg, jax.random.PRNGKey(3))
    assert out.shape == (n, d)
    assert bool(jnp.all(jnp.isfinite(out)))
    # differentiable
    g = jax.grad(lambda q: jnp.sum(fn(q, k, v, cfg, jax.random.PRNGKey(3))))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_linformer_needs_projections():
    cfg = AttnConfig(kind="linformer", linformer_k=8)
    fn = attention_fn(cfg)
    n, d = 16, 8
    q = jnp.ones((n, d))
    e = jnp.ones((n, 8)) / 8.0
    out = fn(q, q, q, cfg, jax.random.PRNGKey(0), proj_e=e, proj_f=e)
    assert out.shape == (n, d)


def test_param_specs_cover_init():
    cfg = make_cfg(PRETRAIN_CFG, "yoso_16")
    specs = M.param_specs(cfg)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    assert len(specs) == len(params)
    for (name, shape), p in zip(specs, params):
        assert p.shape == shape, name
    # conv variant adds per-layer kernels
    cfg_c = make_cfg(PRETRAIN_CFG, "yoso_c_16")
    assert len(M.param_specs(cfg_c)) == len(specs) + cfg.n_layers
    # linformer adds projections
    cfg_l = make_cfg(LRA_CFG, "linformer")
    names = [n for n, _ in M.param_specs(cfg_l)]
    assert "layer0.lin_e" in names and "layer1.lin_f" in names


@pytest.mark.parametrize("variant", ["softmax", "yoso_16", "yoso_e",
                                     "nystrom", "performer", "none"])
def test_train_step_learns(variant):
    base = LRA_CFG if variant in ("nystrom", "performer", "none") else PRETRAIN_CFG
    task = "cls" if base is LRA_CFG else "pretrain"
    cfg = make_cfg(base, variant)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = jax.jit(M.make_train_step(cfg, task))
    batch = rand_batch(cfg, task, 4)
    losses = []
    state = (params, m, v)
    for s in range(8):
        out = step(*state, batch, jnp.int32(s), jnp.int32(s), jnp.float32(2e-3))
        state = out[:3]
        losses.append(float(out[3][0]))
    assert np.isfinite(losses).all(), variant
    assert losses[-1] < losses[0], (variant, losses)


def test_eval_step_metrics_layout():
    cfg = make_cfg(PRETRAIN_CFG, "softmax")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ev = jax.jit(M.make_eval_step(cfg, "pretrain"))
    batch = rand_batch(cfg, "pretrain", 4)
    metrics = ev(params, batch, jnp.int32(0))
    assert metrics.shape == (8,)
    # batch size recorded in slot 6
    assert float(metrics[6]) == 4.0


def test_forward_logits_shape():
    cfg = make_cfg(PRETRAIN_CFG, "yoso_16")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    fwd = jax.jit(M.make_forward(cfg, "cls"))
    ids = jnp.ones((2, cfg.max_len), jnp.int32)
    seg = jnp.zeros((2, cfg.max_len), jnp.int32)
    logits = fwd(params, ids, seg, jnp.int32(0))
    assert logits.shape == (2, cfg.n_classes)


def test_adamw_moves_toward_gradient():
    params = [jnp.ones((4,))]
    grads = [jnp.ones((4,))]
    m = [jnp.zeros((4,))]
    v = [jnp.zeros((4,))]
    new_p, new_m, new_v = M.adamw_update(params, grads, m, v,
                                         jnp.int32(500), jnp.float32(0.1))
    assert bool(jnp.all(new_p[0] < params[0]))
    assert bool(jnp.all(new_m[0] > 0))
    assert bool(jnp.all(new_v[0] > 0))


def test_attention_determinism_given_seed():
    cfg = make_cfg(PRETRAIN_CFG, "yoso_16")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ev = jax.jit(M.make_eval_step(cfg, "pretrain"))
    batch = rand_batch(cfg, "pretrain", 2)
    a = ev(params, batch, jnp.int32(5))
    b = ev(params, batch, jnp.int32(5))
    c = ev(params, batch, jnp.int32(6))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    assert not np.allclose(np.asarray(a), np.asarray(c))
