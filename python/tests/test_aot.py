"""AOT pipeline tests: artifact registry, ABI specs, HLO lowering."""

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def test_artifact_list_covers_experiment_index():
    arts = aot.build_artifact_list()
    names = {a.name for a in arts}
    # Table 2 variants
    for v in aot.PRETRAIN_TRAIN:
        assert f"train_pretrain_{v}" in names
    # Figure 5 eval sweep
    for v in aot.PRETRAIN_EVAL:
        assert f"eval_pretrain_{v}" in names
    # Table 3 variants (train + eval)
    for v in aot.LRA_VARIANTS:
        assert f"train_lra_{v}" in names
        assert f"eval_lra_{v}" in names
    # serving + pallas attention ops
    assert "fwd_glue_yoso_32" in names
    assert "attn_yoso_m8_n256" in names
    # no duplicates
    assert len(names) == len(arts)


def test_train_step_abi_counts():
    art = next(a for a in aot.build_artifact_list()
               if a.name == "train_pretrain_yoso_16")
    n_params = art.config["n_params"]
    # inputs: 3 * params + 4 batch + 3 scalars
    assert len(art.inputs) == 3 * n_params + 4 + 3
    # outputs: 3 * params + metrics
    assert len(art.outputs) == 3 * n_params + 1
    assert art.outputs[-1]["name"] == "metrics"
    assert art.outputs[-1]["shape"] == [8]
    # ABI order: params, adam_m, adam_v
    assert art.inputs[0]["name"].startswith("param:")
    assert art.inputs[n_params]["name"].startswith("adam_m:")
    assert art.inputs[2 * n_params]["name"].startswith("adam_v:")
    assert art.inputs[-1]["name"] == "lr"


def test_example_args_match_input_specs():
    for art in aot.build_artifact_list():
        assert len(art.example_args) == len(art.inputs), art.name
        for struct, spec in zip(art.example_args, art.inputs):
            assert list(struct.shape) == spec["shape"], (art.name, spec)


@pytest.mark.parametrize("name", ["attn_softmax_n256", "eval_lra_none"])
def test_lowering_produces_parseable_hlo(name):
    art = next(a for a in aot.build_artifact_list() if a.name == name)
    lowered = jax.jit(art.fn, keep_unused=True).lower(*art.example_args)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # one parameter per ABI input (keep_unused guarantees this)
    assert text.count("parameter(") >= len(art.inputs)
    assert "ROOT" in text


def test_linformer_artifact_has_projection_params():
    art = next(a for a in aot.build_artifact_list()
               if a.name == "train_lra_linformer")
    names = [s["name"] for s in art.inputs]
    assert "param:layer0.lin_e" in names
    assert "param:layer1.lin_f" in names


def test_conv_variant_has_kernel_params():
    art = next(a for a in aot.build_artifact_list()
               if a.name == "train_pretrain_yoso_c_16")
    names = [s["name"] for s in art.inputs]
    assert "param:layer0.conv_k" in names


def test_attention_config_registry_consistent():
    for name, cfg in aot.ATTN.items():
        if name.startswith("star_"):
            assert cfg.backward == "exact", name
        if name.startswith(("yoso_", "star_yoso_")) and name[-1].isdigit():
            m = int(name.rsplit("_", 1)[1])
            assert cfg.n_hashes == m, name
        if "_c_" in name:
            assert cfg.conv_size > 0, name


def test_eval_metrics_consistent_between_batches():
    """Same params + same batch -> identical metrics (determinism)."""
    cfg = aot.make_cfg(aot.PRETRAIN_CFG, "softmax")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ev = jax.jit(M.make_eval_step(cfg, "pretrain"))
    import numpy as np
    rng = np.random.default_rng(0)
    b, n = 4, cfg.max_len
    ids = jnp.asarray(rng.integers(5, 100, size=(b, n)).astype(np.int32))
    seg = jnp.zeros((b, n), jnp.int32)
    labels = jnp.where(jnp.asarray(rng.random((b, n))) < 0.15, ids, -1)
    sop = jnp.zeros((b,), jnp.int32)
    m1 = ev(params, [ids, seg, labels, sop], jnp.int32(3))
    m2 = ev(params, [ids, seg, labels, sop], jnp.int32(3))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
