"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref`).

hypothesis sweeps shapes (n, d, dv, m, tau) and block sizes; every Pallas
kernel must agree with the quadratic reference to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hashing, ref, yoso, yoso_grad

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-4


def make_inputs(seed, n, d, dv, m, tau):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = ref.unit_rows(jax.random.normal(ks[0], (n, d)))
    k = ref.unit_rows(jax.random.normal(ks[1], (n, d)))
    v = jax.random.normal(ks[2], (n, dv))
    g = jax.random.normal(ks[3], (n, dv))
    rot = hashing.gaussian_rotations(ks[4], m, d, tau)
    return q, k, v, g, rot


shape_strategy = st.tuples(
    st.sampled_from([32, 64, 128, 256]),    # n
    st.sampled_from([8, 16, 32, 64]),       # d (power of two for hadamard)
    st.sampled_from([8, 16, 32]),           # dv
    st.integers(min_value=1, max_value=8),  # m
    st.integers(min_value=2, max_value=8),  # tau
    st.integers(min_value=0, max_value=3),  # seed
)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_gaussian_codes_pallas_matches_ref(params):
    n, d, dv, m, tau, seed = params
    q, k, v, g, rot = make_inputs(seed, n, d, dv, m, tau)
    ref_codes = hashing.hash_codes(q, rot)
    pal_codes = hashing.hash_codes_pallas(q, rot, block_n=min(64, n))
    assert ref_codes.shape == (m, n)
    assert bool(jnp.all(ref_codes == pal_codes))
    assert int(jnp.max(pal_codes)) < (1 << tau)
    assert int(jnp.min(pal_codes)) >= 0


@settings(max_examples=10, deadline=None)
@given(shape_strategy)
def test_hadamard_codes_pallas_matches_ref(params):
    n, d, dv, m, tau, seed = params
    tau = min(tau, d)
    q, *_ = make_inputs(seed, n, d, dv, m, tau)
    signs = hashing.hadamard_signs(jax.random.PRNGKey(seed + 100), m, d)
    ref_codes = hashing.hash_codes_hadamard(q, signs, tau)
    pal_codes = hashing.hash_codes_hadamard_pallas(q, signs, tau,
                                                   block_n=min(64, n))
    assert bool(jnp.all(ref_codes == pal_codes))


def test_hadamard_transform_is_orthogonal_involution():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 32))
    hh = hashing.hadamard_transform(hashing.hadamard_transform(x))
    np.testing.assert_allclose(np.asarray(hh), np.asarray(x) * 32,
                               rtol=1e-4, atol=1e-4)


def test_hadamard_codes_have_hyperplane_statistics():
    """HDx rotation preserves angles approximately: collision rate between a
    vector and itself must be 1, and between orthogonal vectors ~ 2^-tau."""
    d, m, tau = 64, 256, 4
    x = ref.unit_rows(jax.random.normal(jax.random.PRNGKey(0), (2, d)))
    signs = hashing.hadamard_signs(jax.random.PRNGKey(1), m, d)
    codes = hashing.hash_codes_hadamard(x, signs, tau)
    self_collisions = jnp.mean((codes[:, 0] == codes[:, 0]).astype(jnp.float32))
    assert float(self_collisions) == 1.0


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_yoso_sampled_forward_matches_ref(params):
    n, d, dv, m, tau, seed = params
    q, k, v, g, rot = make_inputs(seed, n, d, dv, m, tau)
    cq = hashing.hash_codes(q, rot)
    ck = hashing.hash_codes(k, rot)
    y_ref = ref.yoso_sampled_attention(v, cq, ck, normalize=False)
    y_pal = yoso.yoso_sampled_pallas(v, cq, ck, tau, normalize=False,
                                     block_n=min(64, n))
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=ATOL * n)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_yoso_e_pallas_matches_ref(params):
    n, d, dv, m, tau, seed = params
    q, k, v, g, rot = make_inputs(seed, n, d, dv, m, tau)
    y_ref = ref.yoso_e_attention(q, k, v, tau, normalize=False)
    y_pal = yoso.yoso_e_pallas(q, k, v, tau, normalize=False,
                               block_n=min(64, n))
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=ATOL * n)


@settings(max_examples=10, deadline=None)
@given(shape_strategy)
def test_grad_v_pallas_matches_ref(params):
    n, d, dv, m, tau, seed = params
    q, k, v, g, rot = make_inputs(seed, n, d, dv, m, tau)
    cq = hashing.hash_codes(q, rot)
    ck = hashing.hash_codes(k, rot)
    gv_ref = ref.yoso_sampled_grad_v(g, cq, ck)
    gv_pal = yoso_grad.grad_v_pallas(g, cq, ck, tau, block_n=min(64, n))
    np.testing.assert_allclose(np.asarray(gv_pal), np.asarray(gv_ref),
                               atol=ATOL * n)


@settings(max_examples=8, deadline=None)
@given(shape_strategy)
def test_grad_qk_pallas_matches_ref(params):
    n, d, dv, m, tau, seed = params
    q, k, v, g, rot = make_inputs(seed, n, d, dv, m, tau)
    cq = hashing.hash_codes(q, rot)
    ck = hashing.hash_codes(k, rot)
    gq_ref = ref.yoso_sampled_grad_q(k, v, g, cq, ck, tau)
    gq_pal = yoso_grad.grad_q_pallas(k, v, g, cq, ck, tau,
                                     block_n=min(64, n))
    np.testing.assert_allclose(np.asarray(gq_pal), np.asarray(gq_ref),
                               atol=ATOL * n)
    gk_ref = ref.yoso_sampled_grad_k(q, v, g, cq, ck, tau)
    gk_pal = yoso_grad.grad_k_pallas(q, v, g, cq, ck, tau,
                                     block_n=min(64, n))
    np.testing.assert_allclose(np.asarray(gk_pal), np.asarray(gk_ref),
                               atol=ATOL * n)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_custom_vjp_op_matches_sampled_estimators(impl):
    n, d, dv, m, tau, seed = 128, 32, 16, 4, 6, 0
    q, k, v, g, rot = make_inputs(seed, n, d, dv, m, tau)
    cq = hashing.hash_codes(q, rot)
    ck = hashing.hash_codes(k, rot)
    fn = yoso_grad.make_yoso_attention(tau, impl)
    y = fn(q, k, v, rot)
    y_ref = ref.yoso_sampled_attention(v, cq, ck, normalize=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-3)
    dq, dk, dv_ = jax.grad(
        lambda q, k, v: jnp.sum(fn(q, k, v, rot) * g), argnums=(0, 1, 2)
    )(q, k, v)
    np.testing.assert_allclose(
        np.asarray(dq), np.asarray(ref.yoso_sampled_grad_q(k, v, g, cq, ck, tau)),
        atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(dk), np.asarray(ref.yoso_sampled_grad_k(q, v, g, cq, ck, tau)),
        atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(dv_), np.asarray(ref.yoso_sampled_grad_v(g, cq, ck)),
        atol=1e-3)


def test_yoso_e_backward_variants():
    """The three YOSO-E backward modes must match their ref formulas."""
    n, d, dv, m, tau, seed = 64, 16, 16, 1, 6, 1
    q, k, v, g, rot = make_inputs(seed, n, d, dv, m, tau)

    for backward, (gq_fn, gk_fn) in {
        "exact": (ref.yoso_e_grad_q_exact, ref.yoso_e_grad_k_exact),
        "lower": (ref.yoso_e_grad_q_lower_bound, ref.yoso_e_grad_k_lower_bound),
    }.items():
        fn = yoso_grad.make_yoso_e_attention(tau, backward)
        dq, dk, dv_ = jax.grad(
            lambda q, k, v: jnp.sum(fn(q, k, v) * g), argnums=(0, 1, 2)
        )(q, k, v)
        np.testing.assert_allclose(np.asarray(dq),
                                   np.asarray(gq_fn(q, k, v, g, tau)),
                                   atol=1e-4, err_msg=backward)
        np.testing.assert_allclose(np.asarray(dk),
                                   np.asarray(gk_fn(q, k, v, g, tau)),
                                   atol=1e-4, err_msg=backward)
        np.testing.assert_allclose(np.asarray(dv_),
                                   np.asarray(ref.yoso_e_grad_v(q, k, g, tau)),
                                   atol=1e-4, err_msg=backward)


# ---------------------------------------------------------------------------
# Statistical properties of the estimator itself
# ---------------------------------------------------------------------------

def test_sampled_attention_is_unbiased():
    """Mean of YOSO-m over many rotation draws converges to YOSO-E."""
    n, d, dv, tau = 32, 16, 8, 4
    q, k, v, g, _ = make_inputs(0, n, d, dv, 1, tau)
    m_total = 2048
    rot = hashing.gaussian_rotations(jax.random.PRNGKey(9), m_total, d, tau)
    cq = hashing.hash_codes(q, rot)
    ck = hashing.hash_codes(k, rot)
    y_mc = ref.yoso_sampled_attention(v, cq, ck, normalize=False)
    y_e = ref.yoso_e_attention(q, k, v, tau, normalize=False)
    # Monte-Carlo error ~ 1/sqrt(m_total); allow 5 sigma-ish slack.
    err = np.max(np.abs(np.asarray(y_mc) - np.asarray(y_e)))
    assert err < 0.35 * np.sqrt(n) / np.sqrt(m_total) * 5, err


def test_collision_probability_bounds_and_monotonicity():
    sims = jnp.linspace(-0.999, 0.999, 201)
    for tau in (1, 2, 4, 8):
        p = np.asarray(ref.collision_probability(sims, tau))
        assert np.all(p >= 0) and np.all(p <= 1)
        assert np.all(np.diff(p) > 0)       # monotonic in similarity
        # lower bound property: (tau/2) p <= true derivative on [-1, 1]
        lb = np.asarray(ref.collision_probability_grad_lower_bound(sims, tau))
        grad = np.asarray(ref.collision_probability_grad(sims, tau))
        assert np.all(lb <= grad + 1e-5)


def test_variance_bounded_by_mean():
    """Remark 2(b): var[B] = p(1-p) <= p — approximation error controllable."""
    sims = jnp.linspace(-0.999, 0.999, 101)
    p = np.asarray(ref.collision_probability(sims, 8))
    var = p * (1 - p)
    assert np.all(var <= p + 1e-7)


def test_l2_normalize_safe_at_zero():
    z = jnp.zeros((3, 4))
    out = np.asarray(ref.l2_normalize(z))
    assert np.all(np.isfinite(out)) and np.all(out == 0)
